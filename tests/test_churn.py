"""Fault-injection layer (PR 7): churn schedules, the NODE_DOWN /
NODE_UP drain-and-re-route rail, time-varying per-node delay, the
``slo_aware`` router, and deadline/SLO accounting — conservation,
K=1 bitwise equivalence, and request-for-request parity against the
Python reference cluster."""
import numpy as np
import pytest

from repro.api import (ClusterSpec, DelaySchedule, ExperimentSpec,
                       PeriodicChurn, SyntheticTrace, run_experiment)
from repro.core.jax_engine import slo_attainment

SRC = SyntheticTrace.make(n_functions=12, n_requests=400, seed=3,
                          utilization=0.25)
_ARR = SRC.arrays()["arrival"]
SPAN = float(_ARR.max())
# windows anchored to the trace's own timeline so they always cut
# through live work whatever the generator produces
T30, T45, T60 = (float(np.quantile(_ARR, q)) for q in (0.3, 0.45, 0.6))
EXACT = dict(traces=[SRC], capacities=(3,), queue_cap=256,
             stream=False, keep_per_request=True)


def _ref(policy, cs, **kw):
    from repro.cluster.reference import simulate_cluster_reference
    return simulate_cluster_reference(SRC.to_trace(), policy, cs,
                                      capacity=3, **kw)


def _assert_parity(rs, ref, policy, msg=""):
    np.testing.assert_allclose(rs.value("response", policy=policy),
                               ref["response"], rtol=1e-9, atol=1e-9,
                               err_msg=msg)
    assert int(rs.value("cold_starts", policy=policy)) \
        == ref["cold_starts"], msg
    np.testing.assert_array_equal(
        rs.value("node_done", policy=policy), ref["node_done"],
        err_msg=msg)


# ----------------------------------------------------- spec hardening
def test_churn_spec_validation_errors():
    with pytest.raises(ValueError, match="churn\\[1\\]"):
        ClusterSpec(n_nodes=2, router="jsq2",
                    churn=(None, ((3.0, 2.0),))).validate()
    with pytest.raises(ValueError, match="strictly increasing"):
        ClusterSpec(n_nodes=1, router="jsq2",
                    churn=(((1.0, 5.0), (4.0, 8.0)),)).validate()
    with pytest.raises(ValueError, match="NaN"):
        ClusterSpec(n_nodes=1, router="jsq2",
                    churn=(((float("nan"), 2.0),),)).validate()
    with pytest.raises(ValueError, match="duty"):
        ClusterSpec(router="jsq2",
                    churn=PeriodicChurn(10.0, duty=0.0)).validate()
    with pytest.raises(ValueError, match="period"):
        ClusterSpec(router="jsq2",
                    churn=PeriodicChurn(-1.0)).validate()
    with pytest.raises(ValueError, match="churn"):
        ClusterSpec(n_nodes=3, router="jsq2",
                    churn=(None, ())).validate()
    with pytest.raises(ValueError, match="net_delay"):
        ClusterSpec(net_delay=float("nan")).validate()
    with pytest.raises(ValueError, match="net_delay"):
        ClusterSpec(net_delay=-0.5).validate()
    with pytest.raises(ValueError, match="node_capacity"):
        ClusterSpec(n_nodes=2, node_capacity=(4, 0)).validate()
    with pytest.raises(ValueError, match="times must start at 0"):
        DelaySchedule(times=(1.0,), values=(0.1,)).validate()
    with pytest.raises(ValueError, match="strictly increasing"):
        DelaySchedule(times=(0.0, 2.0, 2.0),
                      values=(0.1, 0.2, 0.3)).validate()
    # a PeriodicChurn broadcasts to every node
    cs = ClusterSpec(n_nodes=3, router="jsq2",
                     churn=PeriodicChurn(10.0, duty=0.5)).validate()
    assert len(cs.churn) == 3 and cs.has_churn()
    assert "+churn" in cs.label


def test_static_tier_rejects_churn_and_delay_schedules():
    cs = ClusterSpec(n_nodes=2, router="hash",
                     churn=(((T30, T45),), None))
    with pytest.raises(ValueError, match="static"):
        run_experiment(ExperimentSpec(
            traces=[SRC], policies=("esff",), capacities=(3,),
            cluster=[cs]))
    ds = DelaySchedule(times=(0.0, 5.0), values=(0.01, 0.2))
    with pytest.raises(ValueError, match="static"):
        run_experiment(ExperimentSpec(
            traces=[SRC], policies=("esff",), capacities=(3,),
            cluster=[ClusterSpec(n_nodes=2, router="hash",
                                 delay_schedule=ds)]))


def test_timer_policy_rejected_under_churn():
    cs = ClusterSpec(n_nodes=2, router="jsq2",
                     churn=(((T30, T45),), None))
    with pytest.raises(ValueError, match="timer"):
        run_experiment(ExperimentSpec(
            traces=[SRC], policies=("openwhisk_v2",),
            capacities=(3,), cluster=[cs]))


# --------------------------------------------------- conservation
def test_conservation_under_mid_flight_node_death():
    """A node dies while holding running + queued work: nothing is
    lost, nothing is double-counted — every request completes exactly
    once, and the survivors match the Python reference request for
    request."""
    cs = ClusterSpec(n_nodes=4, router="jsq2",
                     churn=(((T30, T60),), None, None, None))
    rs = run_experiment(ExperimentSpec(
        policies=("esff", "sff"), cluster=[cs], **EXACT))
    nd = rs["node_done"]
    assert np.all(nd.sum(axis=-1) == SRC.n_requests)
    assert np.all(rs["done"] == SRC.n_requests)
    for policy in ("esff", "sff"):
        resp = rs.value("response", policy=policy)
        assert np.all(resp > 0)
        _assert_parity(rs, _ref(policy, cs), policy, policy)


def test_k1_always_up_churn_bitwise_identical_to_plain_dynamic():
    """Trivial availability schedules (duty=1 periodic, empty window
    lists) lower onto the plain dynamic loop — bitwise, not just
    numerically."""
    grid = dict(policies=("esff",), **EXACT)
    plain = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="jsq2")], **grid))
    for churn in (PeriodicChurn(10.0, duty=1.0), ((),)):
        rs = run_experiment(ExperimentSpec(
            cluster=[ClusterSpec(n_nodes=1, router="jsq2",
                                 churn=churn)], **grid))
        for m in plain.data:
            np.testing.assert_array_equal(
                plain.data[m], rs.data[m], err_msg=str(churn))


# ------------------------------------------------ parity vs reference
@pytest.mark.parametrize("router", ("jsq2", "slo_aware"))
@pytest.mark.parametrize("policy", ("esff", "sff"))
def test_periodic_churn_parity_vs_python_reference(router, policy):
    """K=4 with staggered periodic availability (the LEO-pass shape):
    drains, re-routes and parked arrivals, request for request against
    K ordinary Python engines."""
    cs = ClusterSpec(
        n_nodes=4, router=router,
        churn=(None,
               PeriodicChurn(SPAN / 3, duty=0.7),
               PeriodicChurn(SPAN / 3, duty=0.7, phase=SPAN / 9),
               PeriodicChurn(SPAN / 3, duty=0.7, phase=2 * SPAN / 9)))
    rs = run_experiment(ExperimentSpec(
        policies=(policy,), cluster=[cs], **EXACT))
    assert np.all(rs["done"] == SRC.n_requests)
    _assert_parity(rs, _ref(policy, cs), policy,
                   f"{router}/{policy}")


def test_churn_with_net_delay_parity_vs_python_reference():
    """Churn + heterogeneous constant delay: orphaned requests re-pay
    the delivery leg of whichever node they re-route to; responses
    measure from the raw arrival."""
    cs = ClusterSpec(n_nodes=3, router="jsq2",
                     net_delay=(0.0, 0.013, 0.027),
                     churn=(None, ((T30, T60),), None))
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), cluster=[cs], **EXACT))
    _assert_parity(rs, _ref("esff", cs), "esff")


def test_all_down_window_parks_and_resumes():
    """Every node down over [T30, T45]: arrivals in the window park
    (no loss), resume in FIFO order at NODE_UP, and the whole run
    still matches the reference."""
    win = ((T30, T45),)
    cs = ClusterSpec(n_nodes=2, router="jsq2", churn=(win, win))
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), cluster=[cs], **EXACT))
    assert np.all(rs["done"] == SRC.n_requests)
    resp = rs.value("response", policy="esff")
    arr = SRC.arrays()["arrival"]
    inside = (arr >= T30) & (arr < T45)
    assert inside.any()
    # a parked request cannot start before the cluster comes back
    comp = arr + resp
    assert np.all(comp[inside] >= T45)
    _assert_parity(rs, _ref("esff", cs), "esff")


def test_var_delay_parity_vs_python_reference():
    """Time-varying per-node delay (periodic LEO-style schedule), no
    churn: the router's slo_aware delay term and the deferred rail
    both sample the schedule at decision time."""
    ds = DelaySchedule(times=(0.0, SPAN / 4), values=(0.005, 0.08),
                       period=SPAN / 2)
    for router in ("jsq2", "slo_aware"):
        cs = ClusterSpec(n_nodes=3, router=router,
                         net_delay=(0.0, 0.01, 0.0),
                         delay_schedule=(None, None, ds))
        rs = run_experiment(ExperimentSpec(
            policies=("esff",), cluster=[cs], **EXACT))
        _assert_parity(rs, _ref("esff", cs), "esff", router)


# ------------------------------------------------------ slo routing
def test_slo_aware_registered_and_degrades_to_cold_aware():
    from repro.cluster.routers import available_routers
    assert "slo_aware" in available_routers()
    grid = dict(policies=("esff",), **EXACT)
    a = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=4, router="cold_aware")], **grid))
    b = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=4, router="slo_aware")], **grid))
    for m in ("response", "cold_starts", "node_done"):
        np.testing.assert_array_equal(a[m], b[m], err_msg=m)


# --------------------------------------------------------- deadlines
def test_deadline_miss_matches_exact_responses():
    """Single-node tier: the folded per-function miss counters equal
    a recount over the exact per-request responses, and the derived
    attainment uses the shared helper."""
    dl = 0.35
    rs = run_experiment(ExperimentSpec(
        policies=("esff", "sff"), deadlines=dl, **EXACT))
    fn = SRC.arrays()["fn_id"]
    for pi, policy in enumerate(("esff", "sff")):
        resp = rs.value("response", policy=policy)
        miss = rs.value("deadline_miss", policy=policy)
        expect = np.bincount(fn[resp > dl], minlength=12)
        np.testing.assert_array_equal(miss, expect, err_msg=policy)
    np.testing.assert_array_equal(
        rs["slo_attainment"],
        slo_attainment(rs["deadline_miss"], rs["done"]))


def test_deadlines_through_cluster_tiers_and_reference():
    """The deadlines= knob reaches all three cluster tiers; under
    churn the dynamic tier's counters equal the reference's (raw
    arrival convention)."""
    dl = np.full((12,), 0.35)
    cs = ClusterSpec(n_nodes=3, router="jsq2",
                     churn=(None, ((T30, T60),), None))
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), deadlines=0.35,
        cluster=[None, ClusterSpec(n_nodes=2, router="hash"), cs],
        **EXACT))
    assert rs["deadline_miss"].shape[-1] == 12
    ref = _ref("esff", cs, deadlines=dl)
    np.testing.assert_array_equal(
        rs.value("deadline_miss", policy="esff", cluster=cs.label),
        ref["deadline_miss"])
    np.testing.assert_array_equal(
        rs["slo_attainment"],
        slo_attainment(rs["deadline_miss"], rs["done"]))


def test_deadline_validation_errors():
    with pytest.raises(ValueError, match="deadlines"):
        ExperimentSpec(traces=[SRC], deadlines=-1.0).validate()
    with pytest.raises(ValueError, match="deadlines"):
        ExperimentSpec(traces=[SRC],
                       deadlines=float("nan")).validate()
    with pytest.raises(ValueError, match="12"):
        run_experiment(ExperimentSpec(
            traces=[SRC], policies=("esff",), capacities=(3,),
            deadlines=(0.1, 0.2)))
