"""System-behaviour tests: invariants that must hold for every policy."""
import numpy as np
import pytest

from repro.core import POLICIES, simulate
from repro.traces import synth_azure_trace, trace_from_lists

ALL_POLICIES = list(POLICIES)


@pytest.fixture(scope="module")
def small_trace():
    return synth_azure_trace(n_functions=30, n_requests=1500,
                             utilization=0.2, seed=7)


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_all_requests_complete(small_trace, policy):
    tr = small_trace.head(len(small_trace))
    res = simulate(tr, policy, capacity=8)
    assert len(res.responses) == len(tr)
    assert (res.responses > 0).all()


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_response_at_least_exec(small_trace, policy):
    tr = small_trace.head(len(small_trace))
    res = simulate(tr, policy, capacity=8)
    assert (res.responses >= res.exec_times - 1e-9).all()
    assert (res.slowdowns >= 1 - 1e-9).all()


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_capacity_never_exceeded(small_trace, policy):
    """Reconstruct concurrent busy+cold occupancy from request times."""
    tr = small_trace.head(len(small_trace))
    capacity = 4
    res = simulate(tr, policy, capacity=capacity)
    # busy intervals: (start, completion). Cold occupancy isn't directly
    # visible from requests, so check the weaker-but-sharp busy bound.
    events = []
    for r in tr.requests:
        events.append((r.start, 1))
        events.append((r.completion, -1))
    events.sort()
    conc, peak = 0, 0
    for _, d in events:
        conc += d
        peak = max(peak, conc)
    assert peak <= capacity
    assert res.server.cold_starts >= 1


@pytest.mark.parametrize("policy", ALL_POLICIES)
def test_no_start_before_arrival(small_trace, policy):
    tr = small_trace.head(len(small_trace))
    simulate(tr, policy, capacity=8)
    for r in tr.requests:
        assert r.start >= r.arrival - 1e-9
        assert r.completion == pytest.approx(r.start + r.exec_time)


def test_single_request_pays_exactly_cold_plus_exec():
    for policy in ALL_POLICIES:
        tr = trace_from_lists([0], [0.0], [1.0], cold=[0.8], evict=[0.2])
        res = simulate(tr, policy, capacity=2)
        # OpenWhisk V2 waits its 100 ms head-of-queue threshold first.
        expected = 1.9 if policy == "openwhisk_v2" else 1.8
        assert res.mean_response == pytest.approx(expected), policy


def test_warm_reuse_no_second_cold_start():
    """Two spaced requests of one function: second runs warm everywhere."""
    for policy in ALL_POLICIES:
        tr = trace_from_lists([0, 0], [0.0, 10.0], [1.0, 1.0],
                              cold=[0.8], evict=[0.2])
        res = simulate(tr, policy, capacity=2)
        assert res.server.cold_starts == 1, policy
        assert tr.requests[1].start == pytest.approx(10.0), policy


def test_determinism():
    tr1 = synth_azure_trace(n_functions=20, n_requests=800, seed=42)
    tr2 = synth_azure_trace(n_functions=20, n_requests=800, seed=42)
    r1 = simulate(tr1, "esff", capacity=8)
    r2 = simulate(tr2, "esff", capacity=8)
    np.testing.assert_allclose(r1.responses, r2.responses)
    assert r1.server.cold_starts == r2.server.cold_starts


def test_more_capacity_reduces_cold_starts():
    # Paper Fig. 5(c): in the non-saturated regime, more slots => fewer
    # replacements => less cold-start time. (Under deep saturation the
    # relation inverts — no idle victims — which EXPERIMENTS.md discusses.)
    tr_fn = lambda: synth_azure_trace(n_functions=60, n_requests=6000,
                                      utilization=0.08, seed=11)
    cold, resp = [], []
    for c in (8, 16, 32):
        r = simulate(tr_fn(), "esff", capacity=c)
        cold.append(r.server.cold_starts)
        resp.append(r.mean_response)
    assert cold[0] >= cold[1] >= cold[2]
    assert resp[0] >= resp[1] >= resp[2]


def test_esff_beats_paper_baselines_default_setup():
    """The paper's headline claim under the default-like setup."""
    results = {}
    for p in ("esff", "openwhisk", "openwhisk_v2", "faascache"):
        tr = synth_azure_trace(n_functions=200, n_requests=20_000,
                               utilization=0.2, seed=5)
        results[p] = simulate(tr, p, capacity=16).mean_response
    assert results["esff"] < min(v for k, v in results.items()
                                 if k != "esff")


def test_intensity_scaling():
    tr = synth_azure_trace(n_functions=20, n_requests=500, seed=1)
    sc = tr.scaled(1.4)
    assert sc.requests[10].arrival == pytest.approx(
        tr.requests[10].arrival * 1.4)
    assert sc.requests[10].exec_time == tr.requests[10].exec_time


def test_trace_npz_roundtrip(tmp_path):
    tr = synth_azure_trace(n_functions=10, n_requests=200, seed=2)
    p = str(tmp_path / "t.npz")
    tr.save_npz(p)
    tr2 = type(tr).load_npz(p)
    assert len(tr2) == len(tr)
    assert tr2.requests[5].exec_time == pytest.approx(
        tr.requests[5].exec_time)
    assert tr2.functions[3].cold_start == pytest.approx(
        tr.functions[3].cold_start)
