"""Per-kernel correctness: sweep shapes/dtypes in interpret mode and
assert_allclose against the pure-jnp oracles in kernels/ref.py."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

RNG = np.random.default_rng(0)


def randn(shape, dtype=jnp.float32):
    return jnp.asarray(RNG.normal(size=shape), dtype)


TOL = {jnp.float32: dict(rtol=2e-4, atol=2e-4),
       jnp.bfloat16: dict(rtol=3e-2, atol=3e-2)}


# ----------------------------------------------------------- flash attn
@pytest.mark.parametrize("S,T,H,KVH,D,causal,dtype", [
    (128, 128, 4, 4, 64, True, jnp.float32),
    (128, 128, 4, 1, 64, True, jnp.float32),    # GQA group 4
    (256, 256, 8, 2, 128, True, jnp.bfloat16),  # MXU-aligned bf16
    (128, 128, 2, 2, 64, False, jnp.float32),   # bidirectional
    (100, 180, 4, 2, 64, False, jnp.float32),   # ragged, padding path
])
def test_flash_attention(S, T, H, KVH, D, causal, dtype):
    q = randn((2, S, H, D), dtype)
    k = randn((2, T, KVH, D), dtype)
    v = randn((2, T, KVH, D), dtype)
    got = ops.flash_attention(q, k, v, causal=causal, block_q=64,
                              block_k=64, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_flash_attention_block_shape_invariance():
    q = randn((1, 256, 2, 64))
    k = randn((1, 256, 2, 64))
    v = randn((1, 256, 2, 64))
    outs = [np.asarray(ops.flash_attention(q, k, v, block_q=bq,
                                           block_k=bk, interpret=True))
            for bq, bk in ((64, 64), (128, 64), (64, 128), (256, 256))]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------- decode attn
@pytest.mark.parametrize("T,H,KVH,D,length,dtype", [
    (512, 8, 2, 64, 200, jnp.float32),
    (512, 8, 8, 128, 511, jnp.bfloat16),   # MHA full cache
    (300, 4, 1, 64, 0, jnp.float32),       # length 0 (first token)
    (1024, 16, 2, 128, 700, jnp.bfloat16),
])
def test_decode_attention(T, H, KVH, D, length, dtype):
    B = 2
    q = randn((B, 1, H, D), dtype)
    k = randn((B, T, KVH, D), dtype)
    v = randn((B, T, KVH, D), dtype)
    got = ops.decode_attention(q, k, v, jnp.int32(length), block_k=128,
                               interpret=True)
    want = ref.decode_attention_ref(q, k, v, length)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


# --------------------------------------------------------------- rmsnorm
@pytest.mark.parametrize("shape,dtype", [
    ((4, 128, 512), jnp.float32),
    ((2, 300, 384), jnp.bfloat16),   # ragged rows
    ((1000, 256), jnp.float32),
])
def test_rmsnorm(shape, dtype):
    x = randn(shape, dtype)
    w = randn(shape[-1:], jnp.float32) * 0.1 + 1.0
    got = ops.rmsnorm(x, w, interpret=True)
    want = ref.rmsnorm_ref(x, w)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32),
        **TOL[dtype])


def test_rmsnorm_residual():
    x = randn((3, 100, 256))
    r = randn((3, 100, 256))
    w = randn((256,)) * 0.1 + 1.0
    got_n, got_r = ops.rmsnorm_residual(x, r, w, interpret=True)
    want_n, want_r = ref.rmsnorm_residual_ref(x, r, w)
    np.testing.assert_allclose(np.asarray(got_n), np.asarray(want_n),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(got_r), np.asarray(want_r),
                               rtol=2e-4, atol=2e-4)


# ------------------------------------------------------------- ssd chunk
@pytest.mark.parametrize("b,nc,c,h,p,n", [
    (1, 2, 32, 2, 16, 16),
    (2, 4, 64, 4, 64, 128),   # production-ish chunk
    (1, 1, 16, 8, 32, 64),
])
def test_ssd_chunk(b, nc, c, h, p, n):
    x = randn((b, nc, c, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, nc, c, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    cum = jnp.cumsum(dt * A, axis=2)
    B = randn((b, nc, c, h, n))
    C = randn((b, nc, c, h, n))
    got_y, got_s = ops.ssd_chunk(x, dt, cum, B, C, interpret=True)
    want_y, want_s = ref.ssd_chunk_ref(x, dt, cum, B, C)
    np.testing.assert_allclose(np.asarray(got_y), np.asarray(want_y),
                               rtol=3e-4, atol=3e-4)
    np.testing.assert_allclose(np.asarray(got_s), np.asarray(want_s),
                               rtol=3e-4, atol=3e-4)


def test_ssd_chunk_matches_model_path():
    """Kernel output == models.mamba.ssd_chunked's intra-chunk pieces on
    the same inputs (g=1 head broadcast)."""
    from repro.models.mamba import ssd_chunked
    b, L_, c, h, p, n = 1, 64, 16, 2, 8, 8
    x = randn((b, L_, h, p))
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (b, L_, h)), jnp.float32)
    A = -jnp.asarray(RNG.uniform(0.5, 2.0, (h,)), jnp.float32)
    Bm = randn((b, L_, 1, n))
    Cm = randn((b, L_, 1, n))
    y_model, _ = ssd_chunked(x, dt, A, Bm, Cm, chunk=c)

    nc = L_ // c
    xc = x.reshape(b, nc, c, h, p)
    dtc = dt.reshape(b, nc, c, h)
    cum = jnp.cumsum(dtc * A, axis=2)
    Bh = jnp.repeat(Bm.reshape(b, nc, c, 1, n), h, axis=3)
    Ch = jnp.repeat(Cm.reshape(b, nc, c, 1, n), h, axis=3)
    y_diag, states = ops.ssd_chunk(xc, dtc, cum, Bh, Ch, interpret=True)
    # reconstruct full y: diag + inter-chunk contribution
    S = jnp.zeros((b, h, p, n), jnp.float32)
    total = cum[:, :, -1]
    ys = []
    for i in range(nc):
        y_off = jnp.einsum("bchn,bhpn->bchp",
                           Ch[:, i] * jnp.exp(cum[:, i])[..., None], S)
        ys.append(y_diag[:, i] + y_off)
        S = S * jnp.exp(total[:, i])[:, :, None, None] + states[:, i]
    y_full = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_full),
                               np.asarray(y_model, np.float32),
                               rtol=3e-4, atol=3e-4)


# ----------------------------------------------------------- frp select
@pytest.mark.parametrize("F,seed", [(16, 0), (100, 1), (1000, 2),
                                    (5000, 3)])
def test_frp_select(F, seed):
    r = np.random.default_rng(seed)
    t_e = jnp.asarray(r.uniform(0.001, 10, F), jnp.float32)
    t_l = jnp.asarray(r.uniform(0.5, 1.5, F), jnp.float32)
    t_v = jnp.asarray(r.uniform(0.5, 1.5, F), jnp.float32)
    n_w = jnp.asarray(r.integers(0, 5, F), jnp.int32)
    K = jnp.asarray(r.integers(0, 3, F), jnp.int32)
    tv_j, self_idx = 1.0, 3
    got_w, got_i = ops.frp_select(t_e, t_l, t_v, n_w, K, tv_j, self_idx,
                                  block=256, interpret=True)
    want_w, want_i = ref.frp_select_ref(t_e, t_l, t_v, n_w, K, tv_j,
                                        self_idx)
    if int(want_i) >= 0:
        assert int(got_i) == int(want_i)
        np.testing.assert_allclose(float(got_w), float(want_w),
                                   rtol=1e-5)
    else:
        assert int(got_i) == -1


def test_frp_select_matches_python_esff():
    """Kernel selection == the event-driven ESFF FRP implementation."""
    from repro.core import POLICIES, simulate
    from repro.traces import synth_azure_trace
    from repro.core.esff import ESFF

    tr = synth_azure_trace(n_functions=25, n_requests=800, seed=9)
    checks = []

    class Spy(ESFF):
        def on_exec_done(self, inst, req, t):
            fn = inst.fn_id
            te = np.array([self.est.mean(f.fn_id)
                           for f in self.functions], np.float32)
            tl = np.array([f.cold_start for f in self.functions],
                          np.float32)
            tv = np.array([f.evict for f in self.functions], np.float32)
            nw = np.array([len(self.queues[f.fn_id])
                           for f in self.functions], np.int32)
            K = np.array([self.server.k_count(f.fn_id)
                          for f in self.functions], np.int32)
            w, i = ref.frp_select_ref(te, tl, tv, nw, K,
                                      self.functions[fn].evict, fn)
            # python FRP decision
            w_own = self._weight_current(fn)
            best, bw = fn, w_own
            for g in self.functions:
                j2 = g.fn_id
                if j2 == fn or not self.queues[j2]:
                    continue
                window = g.cold_start + self.functions[fn].evict
                n_e = self._drain_estimate(j2, window)
                if n_e <= 0:
                    continue
                wc = self._weight_candidate(j2, n_e)
                if wc < bw:
                    bw, best = wc, j2
            if len(checks) < 40 and int(i) >= 0:
                kern_best = int(i) if float(w) < w_own else fn
                checks.append((kern_best, best))
            super().on_exec_done(inst, req, t)

    simulate(tr, Spy(), capacity=8)
    assert checks, "no FRP decisions sampled"
    agree = sum(1 for a, b in checks if a == b)
    assert agree == len(checks), f"{agree}/{len(checks)} agree"
