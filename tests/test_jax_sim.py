"""Vectorised JAX ESFF simulator: request-for-request equivalence with
the Python event engine, plus vmap sweep sanity."""
import jax
import numpy as np
import pytest

from repro.core import simulate
from repro.core.jax_sim import simulate_esff_jax, simulate_jax_from_trace
from repro.traces import synth_azure_trace

jax.config.update("jax_enable_x64", True)


@pytest.mark.parametrize("seed,capacity,n", [(5, 8, 400), (1, 4, 300),
                                             (9, 16, 600)])
def test_equivalence_with_python_engine(seed, capacity, n):
    tr = synth_azure_trace(n_functions=20, n_requests=n,
                           utilization=0.2, seed=seed)
    py = simulate(tr, "esff", capacity=capacity)
    jx = simulate_jax_from_trace(tr, capacity=capacity)
    assert jx["overflow"] == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)


def test_beta_hysteresis_reduces_cold_starts():
    tr = synth_azure_trace(n_functions=40, n_requests=2000,
                           utilization=0.4, seed=3)
    base = simulate_jax_from_trace(tr, capacity=8, beta=1.0)
    hyst = simulate_jax_from_trace(tr, capacity=8, beta=2.0)
    assert int(hyst["cold_starts"]) <= int(base["cold_starts"])


def test_vmap_capacity_sweep():
    """Sweep effective capacity via cap_mask under vmap in one call."""
    import jax.numpy as jnp
    tr = synth_azure_trace(n_functions=15, n_requests=300,
                           utilization=0.2, seed=7)
    a = tr.to_arrays()
    C = 16
    masks = jnp.stack([jnp.arange(C) < c for c in (4, 8, 16)])

    def run(mask):
        return simulate_esff_jax(
            jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]), n_fns=tr.n_functions, capacity=C,
            queue_cap=512, cap_mask=mask)

    outs = jax.vmap(run)(masks)
    resp = np.asarray(outs["completion"]) - a["arrival"][None, :]
    means = resp.mean(axis=1)
    # larger capacity must not be (much) worse
    assert means[2] <= means[0] + 1e-6
    # each sweep point matches its individual run
    single = run(masks[1])
    np.testing.assert_allclose(np.asarray(outs["completion"][1]),
                               np.asarray(single["completion"]))
