"""Live serving engine: real cold starts and execution under core
policies; straggler speculative re-execution."""
import numpy as np
import pytest

from repro.core.request import Request
from repro.models.config import ModelConfig
from repro.serving import EdgeServingEngine, ServedFunction


def tiny(name, layers=2, d=32, vocab=128):
    return ModelConfig(name=name, family="dense", n_layers=layers,
                       d_model=d, n_heads=2, n_kv_heads=2,
                       head_dim=d // 2, d_ff=d * 2, vocab_size=vocab,
                       param_dtype="float32", compute_dtype="float32",
                       attn_chunk=16)


@pytest.fixture(scope="module")
def engine():
    fns = [ServedFunction(0, tiny("srv-a"), prompt_len=8, gen_tokens=2,
                          max_len=16),
           ServedFunction(1, tiny("srv-b", layers=3), prompt_len=8,
                          gen_tokens=2, max_len=16)]
    eng = EdgeServingEngine(fns, capacity=2, policy="esff")
    eng.warm_profile()
    return eng


def test_profiles_measured(engine):
    for p in engine.profiles.values():
        assert p.cold_start > 0.01       # real compile time
        assert p.true_mean_exec > 1e-5   # real execution time


def test_all_requests_served(engine):
    reqs = engine.make_requests(10, duration=5.0, seed=3)
    res = engine.run(reqs)
    assert len(res.responses) == 10
    assert (res.responses > 0).all()
    assert res.server.cold_starts >= 1


def test_policies_share_engine_semantics(engine):
    for policy in ("esff", "openwhisk"):
        engine.policy_name = policy
        reqs = engine.make_requests(6, duration=3.0, seed=4)
        res = engine.run(reqs)
        assert len(res.responses) == 6


def test_straggler_speculation(engine):
    engine.policy_name = "esff"
    # factor < 1: any measurement exceeds it once the estimator has >3
    # observations, so speculation must fire deterministically (cache
    # warming makes later measurements sit below the running mean, so a
    # factor near 1.0 is timing-flaky).
    engine.straggler_factor = 0.5
    try:
        reqs = engine.make_requests(12, duration=6.0, seed=5)
        res = engine.run(reqs)
        assert len(engine.stragglers) >= 1
        assert len(res.responses) == 12
    finally:
        engine.straggler_factor = 0.0
        engine.stragglers.clear()
