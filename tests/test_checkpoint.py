"""Checkpointing: atomicity, crc integrity, keep-N GC, async writes,
crash-restart continuity, elastic restore."""
import json
import shutil
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import Checkpointer, latest_step


def tree(seed=0):
    r = np.random.default_rng(seed)
    return {"params": {"w": jnp.asarray(r.normal(size=(8, 16)),
                                        jnp.float32),
                       "b": jnp.asarray(r.normal(size=(16,)),
                                        jnp.bfloat16)},
            "step": jnp.asarray(seed, jnp.int32)}


def test_save_restore_roundtrip(tmp_path):
    ck = Checkpointer(tmp_path)
    t = tree(3)
    ck.save(3, t)
    restored, step = ck.restore(t)
    assert step == 3
    for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_restore_into_shape_structs(tmp_path):
    ck = Checkpointer(tmp_path)
    t = tree(1)
    ck.save(1, t)
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), t)
    restored, _ = ck.restore(target)
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["w"]),
        np.asarray(t["params"]["w"]))


def test_keep_n_gc(tmp_path):
    ck = Checkpointer(tmp_path, keep=2)
    for s in (1, 2, 3, 4):
        ck.save(s, tree(s))
    steps = sorted(int(p.name.split("_")[1])
                   for p in Path(tmp_path).iterdir()
                   if p.name.startswith("step_"))
    assert steps == [3, 4]
    assert latest_step(tmp_path) == 4


def test_async_save(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(7, tree(7), blocking=False)
    ck.wait()
    assert latest_step(tmp_path) == 7


def test_corruption_detected_and_fallback(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, tree(1))
    ck.save(2, tree(2))
    # corrupt the newest checkpoint
    leaf = next((Path(tmp_path) / "step_2").glob("leaf_*.npy"))
    leaf.write_bytes(b"garbage")
    with pytest.raises(Exception):
        ck.restore(tree(0), step=2)
    restored, step = ck.restore(tree(0), strict=False)
    assert step == 1


def test_partial_write_is_invisible(tmp_path):
    """A tmp.step_N dir (simulated crash mid-write) is never restored."""
    ck = Checkpointer(tmp_path)
    ck.save(5, tree(5))
    (Path(tmp_path) / "tmp.step_9").mkdir()
    assert latest_step(tmp_path) == 5
    _, step = ck.restore(tree(0))
    assert step == 5


def test_shape_mismatch_raises(tmp_path):
    ck = Checkpointer(tmp_path)
    ck.save(1, tree(1))
    bad = {"params": {"w": jnp.zeros((4, 4)), "b": jnp.zeros((16,))},
           "step": jnp.zeros((), jnp.int32)}
    with pytest.raises(ValueError):
        ck.restore(bad, step=1)


def test_crash_restart_training_continuity(tmp_path):
    """Train 30 steps with a crash at 20; resumed run must match an
    uninterrupted run exactly (same data order, same state)."""
    from repro.launch.train import train

    out1 = tmp_path / "a"
    with pytest.raises(RuntimeError):
        train("qwen3-4b", smoke=True, steps=30, global_batch=4,
              seq_len=32, ckpt_every=10, out=str(out1), fail_at=20,
              seed=11, log_every=100)
    params_resumed, _ = train("qwen3-4b", smoke=True, steps=30,
                              global_batch=4, seq_len=32, ckpt_every=10,
                              out=str(out1), seed=11, log_every=100)

    out2 = tmp_path / "b"
    params_clean, _ = train("qwen3-4b", smoke=True, steps=30,
                            global_batch=4, seq_len=32, ckpt_every=10,
                            out=str(out2), seed=11, log_every=100)
    for a, b in zip(jax.tree.leaves(params_resumed),
                    jax.tree.leaves(params_clean)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32),
                                   rtol=2e-3, atol=2e-3)
