"""Multi-node cluster subsystem: routing invariants, K=1 bitwise
equivalence with the single-node engine, request-for-request parity of
both routing tiers against the straightforward Python reference
cluster, and the ClusterSpec/router-registry API surface."""
import numpy as np
import pytest

from repro.api import (ClusterSpec, ExperimentSpec, ResultSet,
                       SyntheticTrace, register_router, run_experiment,
                       unregister_router)
from repro.cluster.routers import (ROUTERS, StaticRouter, mix32_jax,
                                   mix32_np, mix32_py)
from repro.cluster.static import build_node_streams

SRC = SyntheticTrace.make(n_functions=12, n_requests=400, seed=3,
                          utilization=0.25)
GRID = dict(traces=[SRC], policies=("esff", "sff"), capacities=(6,),
            queue_cap=256)
STATIC_ROUTERS = ("hash", "round_robin", "weighted_random")


@pytest.fixture(scope="module")
def plain():
    return run_experiment(ExperimentSpec(**GRID)).check()


# ---------------------------------------------------------- hash parity
def test_mix32_variants_agree():
    ids = np.arange(1000)
    for seed in (0, 7, 12345):
        py = np.array([mix32_py(i, seed) for i in ids])
        np.testing.assert_array_equal(py, mix32_np(ids, seed))
        np.testing.assert_array_equal(
            py, np.asarray(mix32_jax(ids, seed)).astype(np.int64))


# ------------------------------------------------------- K=1 bitwise
def test_k1_cluster_bitwise_identical_to_single_node(plain):
    """A 1-node cluster with zero network delay must be bitwise the
    single-node engine — on the static fast path AND through the
    dynamic routers' K-node event loop."""
    rs = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="hash"),
                 ClusterSpec(n_nodes=1, router="round_robin"),
                 ClusterSpec(n_nodes=1, router="jsq2"),
                 ClusterSpec(n_nodes=1, router="cold_aware")], **GRID))
    assert rs.dims[-1] == "cluster"
    for u, lab in enumerate(rs.coords["cluster"]):
        for m in plain.data:
            np.testing.assert_array_equal(
                plain.data[m], np.take(rs.data[m], u, axis=4),
                err_msg=f"{lab}/{m}")


# ------------------------------------------------- routing conservation
def test_static_partition_routes_every_request_exactly_once():
    a = SRC.arrays()
    N = len(a["fn_id"])
    for name in STATIC_ROUTERS:
        cs = ClusterSpec(n_nodes=4, router=name)
        assign, streams, n_live, index = build_node_streams(a, cs)
        assert assign.shape == (N,)
        assert assign.min() >= 0 and assign.max() < 4
        # the per-node index sets partition [0, N)
        allidx = np.concatenate(index)
        assert len(allidx) == N
        assert np.array_equal(np.sort(allidx), np.arange(N))
        assert n_live.sum() == N
        # each sub-stream preserves arrival order and function ids
        for k in range(4):
            nk = int(n_live[k])
            assert np.array_equal(streams["fn_id"][k, :nk],
                                  a["fn_id"][index[k]])
            arr = streams["arrival"][k, :nk]
            assert np.all(np.diff(arr) >= 0)


def test_dynamic_cluster_conserves_requests():
    rs = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=4, router="jsq2"),
                 ClusterSpec(n_nodes=4, router="cold_aware")],
        **GRID)).check()
    nd = rs["node_done"]          # (P, T, K, B, U, nodes)
    assert np.all(nd.sum(axis=-1) == SRC.n_requests)
    assert np.all(rs["done"] == SRC.n_requests)


# -------------------------------------------- node-order invariance
class _PermutedHash(StaticRouter):
    """Hash routing with relabeled node ids — same partition, nodes
    numbered differently."""

    name = "perm_hash"

    def __init__(self, perm):
        self.perm = np.asarray(perm, np.int32)

    def assign(self, fn_id, arrival, spec):
        return self.perm[ROUTERS["hash"].assign(fn_id, arrival, spec)]


def test_static_merge_bitwise_invariant_to_node_order():
    perm = [2, 0, 3, 1]
    register_router("perm_hash", _PermutedHash(perm))
    try:
        base = run_experiment(ExperimentSpec(
            cluster=[ClusterSpec(n_nodes=4, router="hash")], **GRID))
        relabeled = run_experiment(ExperimentSpec(
            cluster=[ClusterSpec(n_nodes=4, router="perm_hash")],
            **GRID))
    finally:
        unregister_router("perm_hash")
    for m in base.data:
        a, b = base.data[m], relabeled.data[m]
        if m == "node_done":      # per-node counts permute with ids:
            b = b[..., perm]      # relabeled[perm[k]] == base[k]
        np.testing.assert_array_equal(a, b, err_msg=m)


# ------------------------------------------------ parity vs reference
@pytest.mark.parametrize("router", ("jsq2", "cold_aware"))
@pytest.mark.parametrize("policy", ("esff", "sff", "openwhisk_v2"))
def test_dynamic_router_parity_vs_python_reference(router, policy):
    """K=4 dynamic cluster, request-for-request against K ordinary
    Python engines behind the mirrored router."""
    from repro.cluster.reference import simulate_cluster_reference
    cs = ClusterSpec(n_nodes=4, router=router)
    rs = run_experiment(ExperimentSpec(
        traces=[SRC], policies=(policy,), capacities=(3,),
        queue_cap=256, stream=False, keep_per_request=True,
        cluster=[cs]))
    ref = simulate_cluster_reference(SRC.to_trace(), policy, cs,
                                     capacity=3)
    np.testing.assert_allclose(rs.value("response", policy=policy),
                               ref["response"], rtol=1e-9, atol=1e-9)
    assert int(rs.value("cold_starts", policy=policy)) \
        == ref["cold_starts"]
    np.testing.assert_array_equal(
        rs.value("node_done", policy=policy), ref["node_done"])


def test_dynamic_net_delay_parity_vs_python_reference():
    """Dynamic routing under heterogeneous per-node network delay: the
    router decides at the raw arrival, the request rides the deferred
    in-flight rail, responses are measured from the node-local
    (delayed) arrival — request-for-request against the Python
    reference's NODE_ARRIVAL leg."""
    from repro.cluster.reference import simulate_cluster_reference
    cs = ClusterSpec(n_nodes=4, router="jsq2",
                     net_delay=(0.0, 0.013, 0.027, 0.041))
    for policy in ("esff", "openwhisk_v2"):
        rs = run_experiment(ExperimentSpec(
            traces=[SRC], policies=(policy,), capacities=(3,),
            queue_cap=256, stream=False, keep_per_request=True,
            cluster=[cs]))
        ref = simulate_cluster_reference(SRC.to_trace(), policy, cs,
                                         capacity=3)
        np.testing.assert_allclose(
            rs.value("response", policy=policy), ref["response"],
            rtol=1e-9, atol=1e-9, err_msg=policy)
        assert int(rs.value("cold_starts", policy=policy)) \
            == ref["cold_starts"]
        np.testing.assert_array_equal(
            rs.value("node_done", policy=policy), ref["node_done"])


def test_k1_dynamic_timer_policy_bitwise_identical_to_single_node():
    """The rid-chain timer rail at K=1 must reproduce the single-node
    positional timer rail bit for bit, through both dynamic routers."""
    grid = dict(traces=[SRC], policies=("openwhisk_v2",),
                capacities=(6,), queue_cap=256)
    plain = run_experiment(ExperimentSpec(**grid)).check()
    rs = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="jsq2"),
                 ClusterSpec(n_nodes=1, router="cold_aware")], **grid))
    for u, lab in enumerate(rs.coords["cluster"]):
        for m in plain.data:
            np.testing.assert_array_equal(
                plain.data[m], np.take(rs.data[m], u, axis=4),
                err_msg=f"{lab}/{m}")


@pytest.mark.parametrize("policy,delayed", [("esff", False),
                                            ("openwhisk_v2", True)])
def test_cluster_engine_seg_boundary_bitwise_invariance(policy,
                                                        delayed):
    """The segment-overlay link rails (queue chain, timer chain,
    deferred-arrival chain) must be bitwise invariant to where segment
    boundaries fall: segment lengths 1 and 5 cut every backlog and
    every in-flight deferred event mid-chain, and must reproduce the
    default (SEG=32) results exactly."""
    import jax.numpy as jnp

    from repro.api.registry import get_kernel
    from repro.cluster.engine import _cluster_metrics
    a = SRC.arrays()
    shared = tuple(jnp.asarray(a[k])[None] for k in
                   ("fn_id", "arrival", "exec_time", "cold_start",
                    "evict"))
    K, C = 4, 3
    delays = (jnp.asarray((0.0, 0.013, 0.027, 0.041))
              if delayed else None)
    outs = []
    for seg in (1, 5, 32):
        out = _cluster_metrics(
            *shared, jnp.zeros((1,), jnp.int32),
            jnp.ones((1, K, C), bool), jnp.ones((1,), jnp.float64),
            jnp.float64(0.1), jnp.float64(0.1), delays,
            kernel=get_kernel(policy), router=ROUTERS["jsq2"],
            n_nodes=K, n_fns=12, capacity=C, queue_cap=256,
            stream=False, has_delay=delayed, seg=seg,
            keep_responses=True)
        outs.append({k: np.asarray(v) for k, v in out.items()})
    assert outs[0]["stalled"].sum() == 0
    assert int(outs[0]["done"][0]) == SRC.n_requests
    for other, tag in ((outs[0], "seg=1"), (outs[1], "seg=5")):
        for m in outs[2]:
            np.testing.assert_array_equal(
                other[m], outs[2][m], err_msg=f"{tag}: {m}")


def test_static_path_parity_vs_python_reference():
    """Heterogeneous nodes + per-node network delay through the
    sub-stream fast path, against the same partition replayed on
    Python engines (timer policy included — the static path supports
    the full kernel set)."""
    from repro.cluster.reference import simulate_cluster_reference
    cs = ClusterSpec(n_nodes=3, router="hash",
                     node_capacity=(4, 2, 3),
                     net_delay=(0.0, 0.05, 0.1))
    for policy in ("esff", "openwhisk_v2"):
        rs = run_experiment(ExperimentSpec(
            traces=[SRC], policies=(policy,), capacities=(9,),
            queue_cap=256, stream=False, keep_per_request=True,
            cluster=[cs]))
        ref = simulate_cluster_reference(SRC.to_trace(), policy, cs)
        np.testing.assert_allclose(
            rs.value("response", policy=policy), ref["response"],
            rtol=1e-9, atol=1e-9)
        assert int(rs.value("cold_starts", policy=policy)) \
            == ref["cold_starts"]


# --------------------------------------------------- spec validation
def test_cluster_spec_validation_errors():
    with pytest.raises(ValueError, match="n_nodes"):
        ClusterSpec(n_nodes=0).validate()
    with pytest.raises(KeyError, match="unknown router"):
        ClusterSpec(router="nope").validate()
    with pytest.raises(ValueError, match="node_capacity"):
        ClusterSpec(n_nodes=3, node_capacity=(4, 2)).validate()
    # dynamic routers accept net_delay (deferred-event rail, PR 6)
    ClusterSpec(router="jsq2", net_delay=0.1).validate()
    with pytest.raises(ValueError, match="net_delay"):
        ClusterSpec(net_delay=-0.1).validate()
    with pytest.raises(ValueError, match="weights"):
        ClusterSpec(n_nodes=2, router="weighted_random",
                    weights=(1.0,)).validate()
    with pytest.raises(TypeError, match="ClusterSpec or None"):
        ExperimentSpec(traces=[SRC], cluster=["hash"]).validate()
    with pytest.raises(ValueError, match="capacity axis"):
        ExperimentSpec(traces=[SRC], capacities=(4, 8),
                       cluster=[ClusterSpec(n_nodes=2,
                                            node_capacity=(2, 2))]
                       ).validate()
    with pytest.raises(ValueError, match="host_shard"):
        ExperimentSpec(traces=[SRC], host_shard=(0, 2),
                       cluster=[ClusterSpec()]).validate()
    # a single ClusterSpec is promoted to a one-entry axis
    spec = ExperimentSpec(traces=[SRC], cluster=ClusterSpec()
                          ).validate()
    assert len(spec.cluster) == 1


def test_register_router_errors_and_custom_router(plain):
    with pytest.raises(TypeError, match="Router"):
        register_router("bad", object())
    with pytest.raises(ValueError, match="already registered"):
        register_router("hash", ROUTERS["hash"])

    class _AllToZero(StaticRouter):
        name = "all_zero"

        def assign(self, fn_id, arrival, spec):
            return np.zeros(len(fn_id), np.int32)

    register_router("all_zero", _AllToZero())
    try:
        # everything lands on node 0 (6 slots); node 1 idles — the
        # merged metrics equal the plain 6-slot single-node run
        rs = run_experiment(ExperimentSpec(
            traces=[SRC], policies=("esff", "sff"), capacities=(6,),
            queue_cap=256,
            cluster=[ClusterSpec(n_nodes=2, router="all_zero",
                                 node_capacity=(6, 6))]))
        for m in ("mean_response", "cold_starts", "resp_hist"):
            np.testing.assert_array_equal(
                plain.data[m], np.take(rs.data[m], 0, axis=4),
                err_msg=m)
        assert rs.data["node_done"][0, 0, 0, 0, 0].tolist() \
            == [SRC.n_requests, 0]
    finally:
        unregister_router("all_zero")
    with pytest.raises(KeyError):
        unregister_router("all_zero")


# ------------------------------------------------ ResultSet cluster axis
def test_resultset_cluster_axis_sel_rows_npz(tmp_path):
    rs = run_experiment(ExperimentSpec(
        cluster=[None, ClusterSpec(n_nodes=2, router="hash")], **GRID))
    assert rs.grid_shape == (2, 1, 1, 1, 2)
    assert rs.coords["cluster"] == ["none", "hash:K2"]
    sub = rs.sel(cluster="hash:K2", policy="esff")
    assert sub.grid_shape == (1, 1, 1, 1, 1)
    v = sub.value("mean_response")
    assert v == rs.value("mean_response", policy="esff",
                         cluster="hash:K2")
    rows = list(rs.rows())
    assert len(rows) == 4 and all("cluster" in r for r in rows)
    path = tmp_path / "rs.npz"
    rs.save_npz(path)
    back = ResultSet.load_npz(path)
    assert back.coords == rs.coords and back.dims == rs.dims
    for m in rs.data:
        np.testing.assert_array_equal(back.data[m], rs.data[m])


def test_net_delay_shifts_node_clock():
    """A uniform delay on a 1-node cluster shifts every event by the
    same constant: responses are unchanged up to float associativity,
    and the timeline moves."""
    base = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="hash")], **GRID))
    delayed = run_experiment(ExperimentSpec(
        cluster=[ClusterSpec(n_nodes=1, router="hash",
                             net_delay=5.0)], **GRID))
    np.testing.assert_allclose(delayed["mean_response"],
                               base["mean_response"],
                               rtol=1e-9, atol=1e-9)
    np.testing.assert_array_equal(delayed["cold_starts"],
                                  base["cold_starts"])
