"""Request-level resilience layer (PR 8): failure injection, timeouts,
retries with capped-exponential backoff, load-shedding admission
control and the circuit-breaker router — spec hardening, conservation
(``done + shed + failed_exhausted == N``), bitwise no-fault lowering,
K=1 tier equivalence and request-for-request parity against the Python
reference cluster."""
import numpy as np
import pytest

from repro.api import (ClusterSpec, ExperimentSpec, PeriodicChurn,
                       ResultSet, RetryPolicy, SyntheticTrace,
                       run_experiment)
from tests._hypothesis_compat import given, settings, st

SRC = SyntheticTrace.make(n_functions=12, n_requests=400, seed=3,
                          utilization=0.25)
N = 400
SPAN = float(SRC.arrays()["arrival"].max())
FAULTS = dict(fail_prob=0.2, timeouts=8.0,
              retry=RetryPolicy(max_attempts=3, base=0.05, cap=1.0,
                                jitter=0.3),
              on_overflow="shed", fail_seed=99)
EXACT = dict(traces=[SRC], capacities=(3,), queue_cap=64,
             stream=False, keep_per_request=True)


def _ref(policy, cs, **kw):
    from repro.cluster.reference import simulate_cluster_reference
    return simulate_cluster_reference(SRC.to_trace(), policy, cs,
                                      capacity=3, queue_cap=64, **kw)


def _counts(rs, **sel):
    return {k: int(rs.value(k, **sel))
            for k in ("done", "failed", "timed_out", "retried",
                      "shed", "failed_exhausted")}


# ----------------------------------------------------- spec hardening
def test_resilience_spec_validation_errors():
    ok = dict(traces=[SRC], policies=("esff",), capacities=(3,))
    with pytest.raises(ValueError, match="on_overflow"):
        ExperimentSpec(**ok, on_overflow="drop").validate()
    with pytest.raises(ValueError, match="fail_prob"):
        ExperimentSpec(**ok, fail_prob=1.5).validate()
    with pytest.raises(ValueError, match="fail_prob"):
        ExperimentSpec(**ok, fail_prob=-0.1).validate()
    with pytest.raises(ValueError, match="timeouts"):
        ExperimentSpec(**ok, timeouts=0.0).validate()
    with pytest.raises(TypeError, match="RetryPolicy"):
        ExperimentSpec(**ok, fail_prob=0.1, retry=3).validate()
    with pytest.raises(ValueError, match="does nothing"):
        ExperimentSpec(**ok, retry=RetryPolicy()).validate()
    # timer-arming policies cannot ride the resilience rails
    with pytest.raises(ValueError, match="timers"):
        ExperimentSpec(traces=[SRC], policies=("openwhisk_v2",),
                       capacities=(3,), fail_prob=0.1).validate()


def test_retry_policy_validation():
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=0)
    with pytest.raises(ValueError, match="max_attempts"):
        RetryPolicy(max_attempts=17)
    with pytest.raises(ValueError, match="jitter"):
        RetryPolicy(jitter=1.0)
    with pytest.raises(ValueError, match=">= 0"):
        RetryPolicy(base=-1.0)
    assert RetryPolicy(max_attempts=5, base=0.5).as_tuple() \
        == (5, 0.5, 30.0, 0.0)


def test_backoff_py_equals_jax_bitwise():
    from repro.core.resilience import backoff_jax, backoff_py
    rng = np.random.default_rng(7)
    keys = rng.integers(0, 1 << 20, size=256).astype(np.int32)
    atts = rng.integers(1, 16, size=256).astype(np.int32)
    for base, cap, jitter, seed in ((0.05, 1.0, 0.3, 99),
                                    (1.0, 30.0, 0.0, 0),
                                    (0.5, 4.0, 0.99, 12345)):
        vec = np.asarray(backoff_jax(atts, keys, base, cap, jitter,
                                     seed))
        ref = np.array([backoff_py(int(a), int(k), base, cap, jitter,
                                   seed) for a, k in zip(atts, keys)])
        np.testing.assert_array_equal(vec, ref)


def test_plan_outcomes_semantics():
    from repro.core.resilience import plan_outcomes
    fn = np.zeros(1000, np.int64)
    ex = np.full(1000, 2.0)
    eff, nf, tmo = plan_outcomes(fn, ex, fail_prob=0.3, timeouts=None,
                                 max_attempts=4, n_fns=1, seed=1)
    assert not tmo.any() and (eff == ex).all()
    # leading-failure counts follow a truncated geometric law
    frac1 = (nf >= 1).mean()
    assert 0.25 < frac1 < 0.35
    # timeouts are deterministic: n_fail == max_attempts
    eff, nf, tmo = plan_outcomes(fn, ex, fail_prob=0.0, timeouts=1.5,
                                 max_attempts=4, n_fns=1, seed=1)
    assert tmo.all() and (nf == 4).all() and (eff == 1.5).all()


# ----------------------------------------------- lowering / conservation
def test_no_fault_spec_lowers_bitwise_unchanged():
    """fail_prob=0, timeouts=None, on_overflow='error' must leave every
    tier on the unchanged code path — all arrays bitwise equal."""
    base = dict(traces=[SRC], policies=("esff",), capacities=(3,),
                queue_cap=256, cluster=(None,
                                        ClusterSpec(n_nodes=2,
                                                    router="hash"),
                                        ClusterSpec(n_nodes=2,
                                                    router="jsq2")))
    r0 = run_experiment(ExperimentSpec(**base)).check()
    r1 = run_experiment(ExperimentSpec(**base, fail_prob=0.0,
                                       timeouts=None,
                                       on_overflow="error")).check()
    assert set(r0.data) == set(r1.data)
    for k in r0.data:
        np.testing.assert_array_equal(r0[k], r1[k], err_msg=k)
    assert "shed" not in r0.data and "goodput" not in r0.data


@pytest.mark.parametrize("mode", ["shed", "shed_oldest"])
def test_conservation_across_tiers(mode):
    rs = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(3,),
        queue_cap=8, **{**FAULTS, "on_overflow": mode},
        cluster=(None, ClusterSpec(n_nodes=2, router="hash"),
                 ClusterSpec(n_nodes=2, router="jsq2"),
                 ClusterSpec(n_nodes=2, router="breaker")))).check()
    tot = rs["done"] + rs["shed"] + rs["failed_exhausted"]
    np.testing.assert_array_equal(tot, np.full_like(tot, N))
    np.testing.assert_allclose(rs["goodput"], rs["done"] / N)


def test_overflow_error_mode_flagged_with_coordinate():
    """With shedding disabled a queue overrun is an *error* that names
    the offending cell's full spec coordinate."""
    rs = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(3,),
        queue_cap=2, fail_prob=0.2, fail_seed=99))
    with pytest.raises(RuntimeError, match="shedding disabled"):
        rs.check()
    with pytest.raises(RuntimeError, match="policy='esff'"):
        rs.check()
    # same pressure with shedding on: drops are by design
    ok = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(3,),
        queue_cap=2, fail_prob=0.2, fail_seed=99,
        on_overflow="shed")).check()
    assert int(ok.value("shed")) > 0


def test_check_conservation_identity():
    grid = dict(policy=["esff"], trace=["t"], capacity=[3],
                beta=["default"])
    one = lambda v: np.full((1, 1, 1, 1), v)  # noqa: E731
    data = dict(done=one(8), shed=one(1), failed_exhausted=one(0),
                overflow=one(0), stalled=one(0))
    meta = dict(n_requests=10,
                resilience=dict(on_overflow="shed"))
    with pytest.raises(RuntimeError, match="conservation"):
        ResultSet(data=data, coords=grid, meta=meta).check()
    data["failed_exhausted"] = one(1)
    ResultSet(data=data, coords=grid, meta=meta).check()


def test_stream_equals_exact_under_faults():
    kw = dict(traces=[SRC], policies=("esff",), capacities=(3,),
              queue_cap=64, **FAULTS,
              cluster=(ClusterSpec(n_nodes=2, router="jsq2"),
                       ClusterSpec(n_nodes=2, router="hash")))
    rs = run_experiment(ExperimentSpec(**kw)).check()
    rx = run_experiment(ExperimentSpec(**kw, stream=False)).check()
    np.testing.assert_array_equal(rs["done"], rx["done"])
    np.testing.assert_array_equal(rs["shed"], rx["shed"])
    np.testing.assert_allclose(rs["mean_response"],
                               rx["mean_response"], rtol=1e-9)


# -------------------------------------------------- tier equivalence
def test_k1_cluster_tiers_equal_single_node_under_faults():
    plain = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(3,),
        queue_cap=64, **FAULTS)).check()
    both = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(3,),
        queue_cap=64, **FAULTS,
        cluster=(ClusterSpec(n_nodes=1, router="jsq2"),
                 ClusterSpec(n_nodes=1, router="hash")))).check()
    for ci in range(2):
        for k in ("mean_response", "p99_response", "done", "shed",
                  "failed", "timed_out", "retried",
                  "failed_exhausted", "goodput"):
            np.testing.assert_array_equal(
                both[k][..., ci], plain[k],
                err_msg=f"{k} cluster={both.coords['cluster'][ci]}")


# ------------------------------------------------- reference parity
@pytest.mark.parametrize("router", ["hash", "round_robin", "jsq2",
                                    "cold_aware"])
def test_fault_parity_vs_reference(router):
    """K=4 fault runs are request-for-request equal to the Python
    reference cluster on both tiers."""
    cs = ClusterSpec(n_nodes=4, router=router)
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), cluster=[cs], **EXACT, **FAULTS)).check()
    ref = _ref("esff", cs, **FAULTS)
    np.testing.assert_allclose(rs.value("response", policy="esff"),
                               ref["response"], rtol=1e-9,
                               equal_nan=True)
    eng = _counts(rs, policy="esff")
    assert eng == {k: int(ref[k]) for k in eng}, (router, eng)
    assert eng["done"] + eng["shed"] + eng["failed_exhausted"] == N


def test_breaker_trips_and_recovers_parity():
    cs = ClusterSpec(n_nodes=4, router="breaker")
    kw = dict(FAULTS, fail_prob=0.6)
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), cluster=[cs], **EXACT, **kw)).check()
    ref = _ref("esff", cs, **kw)
    trips = int(rs.value("breaker_trips", policy="esff"))
    assert trips == int(ref["breaker_trips"])
    assert trips > 0
    # recovery: completions keep landing after the last trip
    assert int(rs.value("done", policy="esff")) > 0
    np.testing.assert_allclose(rs.value("response", policy="esff"),
                               ref["response"], rtol=1e-9,
                               equal_nan=True)


def test_churn_plus_faults_parity():
    cs = ClusterSpec(n_nodes=4, router="jsq2",
                     churn=(None, PeriodicChurn(SPAN / 3, duty=0.7),
                            None, None))
    rs = run_experiment(ExperimentSpec(
        policies=("esff",), cluster=[cs], **EXACT, **FAULTS)).check()
    ref = _ref("esff", cs, **FAULTS)
    np.testing.assert_allclose(rs.value("response", policy="esff"),
                               ref["response"], rtol=1e-9,
                               equal_nan=True)
    eng = _counts(rs, policy="esff")
    assert eng == {k: int(ref[k]) for k in eng}


# --------------------------------------------------- property tests
@given(fail_prob=st.floats(0.0, 0.5),
       timeout=st.one_of(st.none(), st.floats(1.0, 20.0)),
       max_attempts=st.integers(1, 5),
       jitter=st.floats(0.0, 0.9),
       mode=st.sampled_from(["shed", "shed_oldest"]),
       churned=st.booleans())
@settings(max_examples=12, deadline=None)
def test_property_conservation_and_trivial_lowering(
        fail_prob, timeout, max_attempts, jitter, mode, churned):
    """Randomised knob combos: conservation holds exactly; all-trivial
    knobs lower bitwise onto the unchanged engine."""
    cs = ClusterSpec(
        n_nodes=2, router="jsq2",
        churn=((None, PeriodicChurn(SPAN / 3, duty=0.7))
               if churned else None))
    base = dict(traces=[SRC], policies=("esff",), capacities=(3,),
                queue_cap=16, cluster=[cs])
    trivial = fail_prob == 0.0 and timeout is None
    spec = ExperimentSpec(
        **base, fail_prob=fail_prob, timeouts=timeout,
        on_overflow=("error" if trivial else mode),
        retry=(None if trivial
               else RetryPolicy(max_attempts=max_attempts, base=0.05,
                                cap=1.0, jitter=jitter)),
        fail_seed=7)
    rs = run_experiment(spec)
    if trivial:
        r0 = run_experiment(ExperimentSpec(**base))
        assert set(rs.data) == set(r0.data)
        for k in r0.data:
            np.testing.assert_array_equal(rs[k], r0[k], err_msg=k)
    else:
        rs.check()
        tot = rs["done"] + rs["shed"] + rs["failed_exhausted"]
        np.testing.assert_array_equal(tot, np.full_like(tot, N))
