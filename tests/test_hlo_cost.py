"""The HLO cost analyzer must agree with XLA on loop-free programs and
correctly multiply while-loop trip counts (which XLA's cost_analysis does
NOT — the motivating bug)."""
import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.roofline.hlo_cost import analyze_hlo


def _flops(fn, *args):
    c = jax.jit(fn).lower(*args).compile()
    mine = analyze_hlo(c.as_text())
    theirs = c.cost_analysis()
    return mine, theirs


def test_matches_xla_on_plain_matmul():
    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    mine, theirs = _flops(lambda a: a @ a, x)
    assert mine.flops == pytest.approx(theirs["flops"], rel=1e-6)
    assert mine.flops == pytest.approx(2 * 256 ** 3, rel=1e-6)


def test_scan_flops_multiplied_by_trip_count():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(a):
        y, _ = lax.scan(lambda c, _: (c @ c, None), a, None, length=12)
        return y

    mine, theirs = _flops(scanned, x)
    one = 2 * 128 ** 3
    # XLA counts the body once; we must count it 12x.
    assert theirs["flops"] == pytest.approx(one, rel=1e-6)
    assert mine.flops == pytest.approx(12 * one, rel=1e-6)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def inner(a):
        y, _ = lax.scan(lambda c, _: (c @ c, None), a, None, length=5)
        return y

    def outer(a):
        y, _ = lax.scan(lambda c, _: (inner(c), None), a, None, length=3)
        return y

    mine, _ = _flops(outer, x)
    assert mine.flops == pytest.approx(15 * 2 * 64 ** 3, rel=1e-6)


def test_einsum_flops():
    a = jax.ShapeDtypeStruct((8, 32, 64), jnp.float32)
    b = jax.ShapeDtypeStruct((64, 128), jnp.float32)
    mine, theirs = _flops(lambda x, w: jnp.einsum("bsd,df->bsf", x, w),
                          a, b)
    assert mine.flops == pytest.approx(2 * 8 * 32 * 64 * 128, rel=1e-6)
    assert mine.flops == pytest.approx(theirs["flops"], rel=1e-6)


def test_bytes_nonzero_and_scaled():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(a):
        y, _ = lax.scan(lambda c, _: (jnp.tanh(c @ c), None), a, None,
                        length=4)
        return y

    c = jax.jit(scanned).lower(x).compile()
    mine = analyze_hlo(c.as_text())
    assert mine.bytes_accessed > 4 * (128 * 128 * 4) * 2


def test_collectives_counted(monkeypatch):
    hlo = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ip, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> (s32[], f32[8]) {
  %x = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %x)
  ROOT %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
}
"""
    got = analyze_hlo(hlo)
    assert got.collective_bytes["all-reduce"] == pytest.approx(7 * 32)
    assert got.collective_counts["all-reduce"] == 7
