"""The HLO cost analyzer must agree with XLA on loop-free programs and
correctly multiply while-loop trip counts (which XLA's cost_analysis does
NOT — the motivating bug)."""
import json
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest
from jax import lax

from repro.roofline.hlo_cost import analyze_hlo

# The XLA-comparison cases run in a subprocess with default XLA_FLAGS:
# importing repro.core.jax_engine (which pytest collection does via the
# engine test modules) sets --xla_cpu_use_thunk_runtime=false before
# the CPU client initialises, and under that legacy runtime XLA:CPU
# lowers matmuls to oneDNN custom-calls whose cost_analysis reports
# flops=-1 — there is nothing to agree with in-process.
_XLA_SCRIPT = textwrap.dedent("""
    import json
    import jax
    import jax.numpy as jnp
    from jax import lax

    from repro.roofline.hlo_cost import analyze_hlo
    from repro.utils.compat import compiled_cost_analysis

    def scanned(a):
        y, _ = lax.scan(lambda c, _: (c @ c, None), a, None, length=12)
        return y

    cases = {
        "matmul": (lambda a: a @ a,
                   [jax.ShapeDtypeStruct((256, 256), jnp.float32)]),
        "scan": (scanned,
                 [jax.ShapeDtypeStruct((128, 128), jnp.float32)]),
        "einsum": (lambda x, w: jnp.einsum("bsd,df->bsf", x, w),
                   [jax.ShapeDtypeStruct((8, 32, 64), jnp.float32),
                    jax.ShapeDtypeStruct((64, 128), jnp.float32)]),
    }
    out = {}
    for name, (fn, args) in cases.items():
        c = jax.jit(fn).lower(*args).compile()
        out[name] = dict(mine=analyze_hlo(c.as_text()).flops,
                         theirs=compiled_cost_analysis(c)["flops"])
    print("RESULT" + json.dumps(out))
""")


@pytest.fixture(scope="module")
def xla_flops():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", _XLA_SCRIPT], env=env,
                       capture_output=True, text=True, timeout=300,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines()
            if l.startswith("RESULT")][0]
    return json.loads(line[len("RESULT"):])


def test_matches_xla_on_plain_matmul(xla_flops):
    got = xla_flops["matmul"]
    assert got["mine"] == pytest.approx(got["theirs"], rel=1e-6)
    assert got["mine"] == pytest.approx(2 * 256 ** 3, rel=1e-6)


def test_scan_flops_multiplied_by_trip_count(xla_flops):
    got = xla_flops["scan"]
    one = 2 * 128 ** 3
    # XLA counts the body once; we must count it 12x.
    assert got["theirs"] == pytest.approx(one, rel=1e-6)
    assert got["mine"] == pytest.approx(12 * one, rel=1e-6)


def test_einsum_flops(xla_flops):
    got = xla_flops["einsum"]
    assert got["mine"] == pytest.approx(2 * 8 * 32 * 64 * 128, rel=1e-6)
    assert got["mine"] == pytest.approx(got["theirs"], rel=1e-6)


def test_nested_scan_multiplies():
    x = jax.ShapeDtypeStruct((64, 64), jnp.float32)

    def inner(a):
        y, _ = lax.scan(lambda c, _: (c @ c, None), a, None, length=5)
        return y

    def outer(a):
        y, _ = lax.scan(lambda c, _: (inner(c), None), a, None, length=3)
        return y

    c = jax.jit(outer).lower(x).compile()
    mine = analyze_hlo(c.as_text())
    assert mine.flops == pytest.approx(15 * 2 * 64 ** 3, rel=1e-6)


def test_bytes_nonzero_and_scaled():
    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)

    def scanned(a):
        y, _ = lax.scan(lambda c, _: (jnp.tanh(c @ c), None), a, None,
                        length=4)
        return y

    c = jax.jit(scanned).lower(x).compile()
    mine = analyze_hlo(c.as_text())
    assert mine.bytes_accessed > 4 * (128 * 128 * 4) * 2


def test_collectives_counted(monkeypatch):
    hlo = """
HloModule test

%cond (p: (s32[], f32[8])) -> pred[] {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %k = s32[] constant(7)
  ROOT %lt = pred[] compare(%i, %k), direction=LT
}

%body (p: (s32[], f32[8])) -> (s32[], f32[8]) {
  %p = (s32[], f32[8]) parameter(0)
  %i = s32[] get-tuple-element(%p), index=0
  %x = f32[8] get-tuple-element(%p), index=1
  %ar = f32[8]{0} all-reduce(%x), replica_groups={}, to_apply=%sum
  %one = s32[] constant(1)
  %ip = s32[] add(%i, %one)
  ROOT %t = (s32[], f32[8]) tuple(%ip, %ar)
}

%sum (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %s = f32[] add(%a, %b)
}

ENTRY %main (x: f32[8]) -> (s32[], f32[8]) {
  %x = f32[8]{0} parameter(0)
  %z = s32[] constant(0)
  %t0 = (s32[], f32[8]) tuple(%z, %x)
  ROOT %w = (s32[], f32[8]) while(%t0), condition=%cond, body=%body
}
"""
    got = analyze_hlo(hlo)
    assert got.collective_bytes["all-reduce"] == pytest.approx(7 * 32)
    assert got.collective_counts["all-reduce"] == 7
