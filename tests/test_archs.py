"""Per-architecture smoke tests (reduced same-family configs, CPU):
one forward/train step asserting output shapes + finiteness, a serve
(prefill -> decode) pass, and decode-vs-prefill logit consistency."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_arch
from repro.models import build_model

ARCH_NAMES = [
    "internlm2-20b", "qwen3-14b", "qwen1.5-4b", "qwen3-4b", "mamba2-780m",
    "deepseek-moe-16b", "deepseek-v3-671b", "whisper-tiny", "zamba2-2.7b",
    "internvl2-76b",
]


def mk_batch(cfg, B, S, seed=0, labels=True):
    rng = np.random.default_rng(seed)
    batch = {"tokens": jnp.array(rng.integers(0, cfg.vocab_size, (B, S)))}
    if labels:
        batch["labels"] = jnp.array(
            rng.integers(0, cfg.vocab_size, (B, S)))
    if cfg.family == "vlm":
        batch["tokens"] = batch["tokens"][:, :S - cfg.n_patches]
        batch["patch_embeds"] = jnp.array(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jnp.array(
            rng.normal(size=(B, cfg.n_enc_positions, cfg.d_model)),
            jnp.float32)
    return batch


@pytest.fixture(scope="module", params=ARCH_NAMES)
def arch(request):
    cfg = get_arch(request.param).smoke()
    model = build_model(cfg)
    params, specs = model.init(jax.random.key(0))
    return request.param, cfg, model, params, specs


def test_full_config_fields(arch):
    name, *_ = arch
    full = get_arch(name)
    assert full.name == name
    # spot-check the published numbers survived
    table = {
        "internlm2-20b": (48, 6144, 48, 8, 16384, 92544),
        "qwen3-14b": (40, 5120, 40, 8, 17408, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "deepseek-v3-671b": (61, 7168, 128, 128, 18432, 129280),
        "deepseek-moe-16b": (28, 2048, 16, 16, 10944, 102400),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "internvl2-76b": (80, 8192, 64, 8, 28672, 128256),
    }
    if name in table:
        L_, d, h, kv, ff, v = table[name]
        assert (full.n_layers, full.d_model, full.n_heads, full.n_kv_heads,
                full.d_ff, full.vocab_size) == (L_, d, h, kv, ff, v)


def test_train_step_shapes_and_finite(arch):
    name, cfg, model, params, _ = arch
    batch = mk_batch(cfg, 2, 32)
    (loss, metrics), grads = jax.jit(
        jax.value_and_grad(lambda p: model.loss(p, batch), has_aux=True)
    )(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), name
    assert jnp.isfinite(metrics["ce"]), name
    for leaf in jax.tree.leaves(grads):
        assert jnp.isfinite(leaf.astype(jnp.float32)).all(), name


def test_serve_path(arch):
    name, cfg, model, params, _ = arch
    B, S, MAX = 2, 32, 64
    batch = mk_batch(cfg, B, S, labels=False)
    cache = model.cache_spec(B, MAX).zeros()
    logits, cache = jax.jit(model.prefill)(params, batch, cache)
    assert logits.shape[0] == B and logits.shape[1] == 1
    assert logits.shape[2] == cfg.padded_vocab
    assert jnp.isfinite(logits).all(), name
    tok = jnp.argmax(logits[:, -1], -1)[:, None]
    step = jax.jit(model.decode_step)
    for _ in range(3):
        logits, cache = step(params, tok, cache)
        assert jnp.isfinite(logits).all(), name
        tok = jnp.argmax(logits[:, -1], -1)[:, None]


def test_decode_matches_prefill(arch):
    """Logits from prefill(S) followed by decode of token S must match
    prefill(S+1)'s last-position logits (cache correctness)."""
    name, cfg, model, params, _ = arch
    if cfg.family == "hybrid":
        pytest.skip("hybrid shared-attn cache keeps a sliding window; "
                    "exact-match check covered by families it composes")
    B, S, MAX = 2, 16, 64
    batch = mk_batch(cfg, B, S + 1, labels=False)
    toks = batch["tokens"]              # vlm: already minus n_patches
    T = toks.shape[1]

    b1 = dict(batch)
    b1["tokens"] = toks[:, :T - 1]
    cache = model.cache_spec(B, MAX).zeros()
    _, cache = jax.jit(model.prefill)(params, b1, cache)
    logits_step, _ = jax.jit(model.decode_step)(
        params, toks[:, T - 1:T], cache)

    b2 = dict(batch)
    b2["tokens"] = toks
    cache2 = model.cache_spec(B, MAX).zeros()
    logits_full, _ = jax.jit(model.prefill)(params, b2, cache2)

    np.testing.assert_allclose(
        np.asarray(logits_step[:, 0], np.float32),
        np.asarray(logits_full[:, -1], np.float32),
        rtol=2e-2, atol=2e-2)


def test_determinism(arch):
    name, cfg, model, params, _ = arch
    batch = mk_batch(cfg, 2, 32)
    l1 = jax.jit(lambda p: model.loss(p, batch)[0])(params)
    l2 = jax.jit(lambda p: model.loss(p, batch)[0])(params)
    assert float(l1) == float(l2)


def test_param_spec_tree_matches(arch):
    """The logical-axis spec tree must mirror the param tree exactly."""
    name, cfg, model, params, specs = arch
    pt = jax.tree.structure(params)
    st = jax.tree.structure(
        specs, is_leaf=lambda x: isinstance(x, tuple) and (
            len(x) == 0 or not isinstance(x[0], dict)))
    assert pt == st, name
    flat_p = jax.tree.leaves(params)
    flat_s = jax.tree.leaves(
        specs, is_leaf=lambda x: isinstance(x, tuple) and (
            len(x) == 0 or not isinstance(x[0], dict)))
    for a, s in zip(flat_p, flat_s):
        assert a.ndim == len(s), (name, a.shape, s)
