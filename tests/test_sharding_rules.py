"""ShardingRules resolution logic (pure unit tests — no devices needed
beyond the default; mesh built over 1 device with abstract axis sizes is
not possible, so we validate against the production mesh geometry by
constructing rule tables directly)."""
import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import get_arch
from repro.distributed.sharding import ShardingRules


def mk_rules(**rules):
    return ShardingRules(rules)


def test_resolve_basic():
    r = mk_rules(batch=("pod", "data"), heads="model", ff="model")
    assert r.resolve(("batch", None, "heads", None)) == \
        P(("pod", "data"), None, "model", None)
    assert r.resolve(("ff",)) == P("model")


def test_resolve_drops_duplicate_mesh_axes():
    # batch claims data; a later fsdp-mapped embed must fall back to None
    r = mk_rules(batch=("pod", "data"), embed=("pod", "data"))
    assert r.resolve(("batch", "seq", "embed")) == \
        P(("pod", "data"), None, None)
    # params (no batch dim) keep the fsdp mapping
    assert r.resolve(("embed", "ff")) == P(("pod", "data"), None)


def test_unknown_axes_replicate():
    r = mk_rules()
    assert r.resolve(("whatever", None)) == P(None, None)


@pytest.mark.parametrize("arch,expect_heads,expect_seq_attn", [
    ("internlm2-20b", True, False),    # 48 % 16 == 0
    ("qwen3-14b", False, True),        # 40 % 16 != 0 -> seq-parallel
    ("qwen1.5-4b", False, True),       # 20 % 16 != 0
    ("qwen3-4b", True, False),         # 32 % 16 == 0
    ("deepseek-v3-671b", True, False), # 128 % 16 == 0
    ("whisper-tiny", False, True),     # 6 % 16 != 0
])
def test_for_config_head_modes(arch, expect_heads, expect_seq_attn):
    # geometry-only: build the rules against a fake mesh-shaped object
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_arch(arch)
    rules = ShardingRules.for_config(cfg, FakeMesh(), "train")
    assert (rules.rules.get("heads") == "model") == expect_heads, arch
    assert bool(rules.rules.get("_seq_attn")) == expect_seq_attn, arch


def test_for_config_fsdp_shards_embed():
    class FakeMesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    cfg = get_arch("deepseek-v3-671b")
    r = ShardingRules.for_config(cfg, FakeMesh(), "train", fsdp=True)
    assert r.rules["embed"] == ("pod", "data")
    assert r.rules["experts"] == "model"
    assert r.rules["lora"] == ("pod", "data")
    r2 = ShardingRules.for_config(cfg, FakeMesh(), "train", fsdp=False)
    assert r2.rules["embed"] is None


def test_decode_rules_shard_cache_seq():
    class FakeMesh:
        axis_names = ("data", "model")
        shape = {"data": 16, "model": 16}
    cfg = get_arch("internlm2-20b")
    r = ShardingRules.for_config(cfg, FakeMesh(), "decode")
    assert r.rules["cache_seq"] == "model"
    # kv heads (8) don't divide 16 -> replicated kv weights
    assert r.rules["kv_heads"] is None
