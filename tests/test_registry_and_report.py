"""Registry completeness, shape-cell rules, roofline report rendering,
and dry-run results sanity (runs against the committed artifacts)."""
import json
from pathlib import Path

import pytest

from repro.configs import ARCHS, SHAPES, get_arch, shape_cells

ASSIGNED = [
    "internlm2-20b", "qwen3-14b", "qwen1.5-4b", "qwen3-4b", "mamba2-780m",
    "deepseek-v3-671b", "deepseek-moe-16b", "whisper-tiny", "zamba2-2.7b",
    "internvl2-76b",
]


def test_all_assigned_archs_registered():
    for a in ASSIGNED:
        cfg = get_arch(a)
        assert cfg.name == a


def test_shape_cells_rules():
    cells = shape_cells()
    assert len(cells) == 32   # 10 archs x 3 + 2 long_500k
    long_archs = {a for a, s in cells if s == "long_500k"}
    assert long_archs == {"mamba2-780m", "zamba2-2.7b"}
    for a in ASSIGNED:
        assert (a, "train_4k") in cells
        assert (a, "prefill_32k") in cells
        assert (a, "decode_32k") in cells


def test_shapes_match_assignment():
    assert SHAPES["train_4k"].seq_len == 4096
    assert SHAPES["train_4k"].global_batch == 256
    assert SHAPES["prefill_32k"].global_batch == 32
    assert SHAPES["decode_32k"].global_batch == 128
    assert SHAPES["long_500k"].seq_len == 524288
    assert SHAPES["long_500k"].global_batch == 1


def test_divisibility_production_mesh():
    """Every arch's TP-sharded dims must divide the 16-wide model axis
    (the dry-run would fail otherwise; this is the fast guard)."""
    for a in ASSIGNED:
        cfg = get_arch(a)
        assert cfg.padded_vocab % 16 == 0, a
        if cfg.d_ff:
            assert cfg.d_ff % 16 == 0, a
        if cfg.n_experts:
            assert cfg.n_experts % 16 == 0, a
        if cfg.ssm_state:
            assert cfg.d_inner % 16 == 0, a


@pytest.mark.skipif(not Path("results/dryrun.jsonl").exists(),
                    reason="dry-run artifacts not present")
def test_dryrun_artifacts_complete_and_clean():
    seen = {}
    for line in Path("results/dryrun.jsonl").read_text().splitlines():
        r = json.loads(line)
        seen[(r["arch"], r["shape"], r["mesh"])] = r
    cells = shape_cells()
    for mesh in ("pod16x16", "pod2x16x16"):
        for a, s in cells:
            key = (a, s, mesh)
            assert key in seen, f"missing cell {key}"
            assert "error" not in seen[key], f"failed cell {key}"
            rf = seen[key]["roofline"]
            assert rf["compute_s"] >= 0
            assert rf["memory_s"] > 0
            assert rf["dominant"] in ("compute", "memory", "collective")


@pytest.mark.skipif(not Path("results/dryrun.jsonl").exists(),
                    reason="dry-run artifacts not present")
def test_roofline_report_renders():
    from benchmarks.roofline_report import dryrun_table, load, \
        roofline_table
    rows = load("results/dryrun.jsonl")
    t1 = dryrun_table(rows)
    t2 = roofline_table(rows)
    assert "internlm2-20b" in t1 and "internlm2-20b" in t2
    assert t2.count("|") > 100
