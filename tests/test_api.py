"""Declarative experiment API: spec validation, ResultSet semantics,
the policy registry, the sweep() deprecation shim's bitwise parity,
and device/host sharding parity."""
import io
import os
import subprocess
import sys
import textwrap
import warnings

import numpy as np
import pytest

from repro.api import (ArrayTrace, ExperimentSpec, NpzTrace, ResultSet,
                       SyntheticTrace, as_trace_source,
                       available_policies, get_kernel, register_policy,
                       run_experiment, unregister_policy)
from repro.traces import synth_azure_trace

SRC = SyntheticTrace.make(n_functions=10, n_requests=300, seed=5,
                          utilization=0.25)
GRID = dict(traces=[SRC], policies=("esff", "sff"),
            capacities=(3, 5), queue_cap=256)


@pytest.fixture(scope="module")
def rs():
    return run_experiment(ExperimentSpec(**GRID)).check()


# -------------------------------------------------------- trace sources
def test_trace_source_coercion_and_views():
    tr = synth_azure_trace(n_functions=10, n_requests=300,
                           utilization=0.25, seed=5)
    from_trace = as_trace_source(tr)
    assert isinstance(from_trace, ArrayTrace)
    a, b = SRC.arrays(), from_trace.arrays()
    for k in a:
        np.testing.assert_array_equal(a[k], b[k])
    # head/scaled views mirror Trace.head / Trace.scaled
    h = SRC.head(100)
    assert h.n_requests == 100 and h.n_functions == 10
    np.testing.assert_array_equal(h.arrays()["arrival"],
                                  a["arrival"][:100])
    s = SRC.scaled(1.5)
    np.testing.assert_array_equal(s.arrays()["arrival"],
                                  a["arrival"] * 1.5)
    assert "head100" in h.label and "scale1.5" in s.label
    # reseeding: synthetic sources support it (through wrappers too)
    assert SRC.with_seed(7).seed == 7
    assert h.with_seed(7).base.seed == 7
    with pytest.raises(TypeError, match="not reseedable"):
        from_trace.with_seed(7)
    with pytest.raises(TypeError, match="trace source"):
        as_trace_source(42)


def test_npz_trace_roundtrip(tmp_path):
    path = tmp_path / "t.npz"
    np.savez_compressed(path, **SRC.arrays())
    src = NpzTrace(path=str(path))
    for k, v in SRC.arrays().items():
        np.testing.assert_array_equal(v, src.arrays()[k])
    with pytest.raises(FileNotFoundError):
        NpzTrace(path=str(tmp_path / "missing.npz")).arrays()


def test_array_trace_validation():
    a = SRC.arrays()
    with pytest.raises(ValueError, match="missing trace column"):
        ArrayTrace.make({k: a[k] for k in ("fn_id", "arrival")}).arrays()
    bad = dict(a)
    bad["exec_time"] = a["exec_time"][:-5]
    with pytest.raises(ValueError, match="disagree on length"):
        ArrayTrace.make(bad).arrays()


# ------------------------------------------------------ spec validation
def test_spec_validation_errors():
    with pytest.raises(KeyError, match="unknown policy 'nope'"):
        ExperimentSpec(traces=[SRC], policies=("nope",)).validate()
    with pytest.raises(ValueError, match="no capacities"):
        ExperimentSpec(traces=[SRC], capacities=()).validate()
    with pytest.raises(ValueError, match="capacities must be positive"):
        ExperimentSpec(traces=[SRC], capacities=(0,)).validate()
    with pytest.raises(ValueError, match="no trace sources"):
        ExperimentSpec(traces=[]).validate()
    with pytest.raises(ValueError, match="host_shard"):
        ExperimentSpec(traces=[SRC], host_shard=(3, 2)).validate()
    with pytest.raises(ValueError, match="keep_per_request"):
        ExperimentSpec(traces=[SRC], keep_per_request=True).validate()
    with pytest.raises(ValueError, match="duplicate policies"):
        ExperimentSpec(traces=[SRC],
                       policies=("esff", "esff")).validate()
    with pytest.raises(TypeError, match="not reseedable"):
        ExperimentSpec(traces=[as_trace_source(SRC.arrays())],
                       seeds=(0, 1)).validate()
    # mismatched trace shapes are caught at lowering with both labels
    with pytest.raises(ValueError, match="must share shape"):
        run_experiment(ExperimentSpec(
            traces=[SRC, SRC.head(100)], policies=("esff",),
            capacities=(3,)))


def test_spec_seed_expansion():
    spec = ExperimentSpec(traces=[SRC], policies=("esff",),
                          capacities=(3,), seeds=(5, 6)).validate()
    labels = [s.label for s in spec.expanded_traces()]
    assert len(labels) == 2 and "seed5" in labels[0] \
        and "seed6" in labels[1]
    assert spec.grid_size() == 2


# ------------------------------------------------------------ ResultSet
def test_resultset_sel_value_rows(rs):
    assert rs.grid_shape == (2, 1, 2, 1)
    sub = rs.sel(policy="esff", capacity=5)
    assert sub.grid_shape == (1, 1, 1, 1)
    v = sub.value("mean_response")
    assert isinstance(v, float)
    assert v == rs.value("mean_response", policy="esff", capacity=5)
    assert rs.sel(capacity=[3, 5]).grid_shape == (2, 1, 2, 1)
    with pytest.raises(KeyError, match="not on the"):
        rs.sel(capacity=99)
    with pytest.raises(KeyError, match="unknown dim"):
        rs.sel(flavour="esff")
    with pytest.raises(KeyError, match="exactly one cell"):
        rs.value("mean_response", policy="esff")
    rows = list(rs.rows())
    assert len(rows) == 4
    assert {r["policy"] for r in rows} == {"esff", "sff"}
    assert all("mean_response" in r and "resp_hist" not in r
               for r in rows)
    buf = io.StringIO()
    rs.to_csv(buf)
    assert buf.getvalue().startswith("policy,trace,capacity,beta")
    assert len(buf.getvalue().splitlines()) == 5


def test_resultset_npz_roundtrip(rs, tmp_path):
    path = tmp_path / "rs.npz"
    rs.save_npz(path)
    back = ResultSet.load_npz(path)
    assert back.coords == rs.coords
    assert set(back.data) == set(rs.data)
    for k in rs.data:
        np.testing.assert_array_equal(back.data[k], rs.data[k])
        assert back.data[k].dtype == rs.data[k].dtype
    np.testing.assert_array_equal(back.computed, rs.computed)
    # and selection still works after the round-trip
    assert back.value("cold_starts", policy="sff", capacity=3) \
        == rs.value("cold_starts", policy="sff", capacity=3)


def test_resultset_check_flags_bad_cells(rs):
    broken = rs.sel()   # copy via identity selection
    broken.data["overflow"] = np.ones_like(broken.data["overflow"])
    with pytest.raises(RuntimeError, match="overflow"):
        broken.check()


# ------------------------------------------------------- host sharding
def test_host_shard_merge_matches_full_run(rs):
    parts = [run_experiment(ExperimentSpec(lane_chunk=1,
                                           host_shard=(i, 3), **GRID))
             for i in range(3)]
    for p in parts:
        assert not p.computed.all()
        with pytest.raises(ValueError, match="not computed"):
            missing = np.argwhere(~p.computed)[0]
            p.value("mean_response",
                    policy=p.coords["policy"][missing[0]],
                    trace=p.coords["trace"][missing[1]],
                    capacity=p.coords["capacity"][missing[2]])
    merged = parts[0].merge(*parts[1:])
    assert merged.computed.all()
    for k in rs.data:
        np.testing.assert_array_equal(merged.data[k], rs.data[k])
    with pytest.raises(ValueError, match="more than one shard"):
        parts[0].merge(parts[0])


def test_host_shard_with_no_chunks_errors():
    with pytest.raises(ValueError, match="no chunks"):
        run_experiment(ExperimentSpec(lane_chunk=64,
                                      host_shard=(50, 99), **GRID))


# -------------------------------------------- multi-trace row grouping
def test_row_split_grid_bitwise_equal(monkeypatch):
    """Big multi-trace grids run one trace row per engine call (the
    stacked (T, N) operand is a batched-gather cliff on XLA:CPU); a
    lane's metrics depend only on its own trace row, so the grouped
    grid must be bitwise the stacked one."""
    import repro.api.runner as runner_mod

    srcs = [SyntheticTrace.make(n_functions=10, n_requests=300,
                                seed=s, utilization=0.25)
            for s in range(4)]
    grid = dict(traces=srcs, policies=("esff", "openwhisk"),
                capacities=(3, 5), queue_cap=256)
    monkeypatch.setattr(runner_mod, "ROW_SPLIT_ELEMS", 1 << 30)
    stacked = run_experiment(ExperimentSpec(**grid))
    assert stacked.meta["row_split"] is False
    monkeypatch.setattr(runner_mod, "ROW_SPLIT_ELEMS", 1)
    split = run_experiment(ExperimentSpec(**grid))
    assert split.meta["row_split"] is True
    for k in stacked.data:
        np.testing.assert_array_equal(split.data[k], stacked.data[k])
    # row boundaries must also survive a lane_chunk that straddles
    # them in the stacked plan
    split_c = run_experiment(ExperimentSpec(lane_chunk=3, **grid))
    for k in stacked.data:
        np.testing.assert_array_equal(split_c.data[k],
                                      stacked.data[k])


# ------------------------------------------------------ policy registry
def test_register_policy_errors_and_custom_kernel():
    from repro.core.jax_policies import ESFFKernel
    with pytest.raises(KeyError, match="unknown policy 'nothere'"):
        get_kernel("nothere")
    with pytest.raises(TypeError, match="PolicyKernel"):
        register_policy("bad", object())
    with pytest.raises(ValueError, match="already registered"):
        register_policy("esff", ESFFKernel("esff"))
    custom = ESFFKernel("esff_custom")
    register_policy("esff_custom", custom)
    try:
        assert "esff_custom" in available_policies()
        assert get_kernel("esff_custom") is custom
        # a registered kernel participates in specs by name; this one
        # is behaviourally identical to esff, so outputs match bitwise
        ref = run_experiment(ExperimentSpec(**GRID))
        out = run_experiment(ExperimentSpec(
            traces=[SRC], policies=("esff_custom",),
            capacities=(3, 5), queue_cap=256))
        for k in out.data:
            np.testing.assert_array_equal(
                out.data[k][0], ref.sel(policy="esff").data[k][0])
    finally:
        unregister_policy("esff_custom")
    assert "esff_custom" not in available_policies()
    with pytest.raises(KeyError):
        unregister_policy("esff_custom")


# -------------------------------------------------- sweep() deprecation
def test_sweep_shim_warns_and_is_bitwise_equal(rs):
    from repro.core.jax_engine import sweep
    tr = synth_azure_trace(n_functions=10, n_requests=300,
                           utilization=0.25, seed=5)
    with pytest.warns(DeprecationWarning, match="ExperimentSpec"):
        legacy = sweep(tr, policies=("esff", "sff"),
                       capacities=(3, 5), queue_cap=256)
    assert legacy["axes"] == dict(policy=["esff", "sff"], trace=1,
                                  capacity=[3, 5], beta=None)
    for k in rs.data:
        np.testing.assert_array_equal(legacy[k], rs.data[k])


def test_keep_per_request_matches_single_run():
    from repro.core.jax_engine import simulate_policy_from_trace
    tr = synth_azure_trace(n_functions=10, n_requests=300,
                           utilization=0.25, seed=5)
    out = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff",), capacities=(5,),
        queue_cap=256, stream=False, keep_per_request=True))
    resp = out.value("response", policy="esff")
    assert resp.shape == (300,)
    single = simulate_policy_from_trace(tr, "esff", 5, queue_cap=256)
    np.testing.assert_array_equal(resp, single["response"])


# ------------------------------------------------------ device sharding
@pytest.mark.slow
def test_two_device_sharded_run_bitwise_identical():
    script = textwrap.dedent("""
        import os
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "")
            + " --xla_force_host_platform_device_count=2")
        import numpy as np
        import jax
        from repro.api import (ExperimentSpec, SyntheticTrace,
                               run_experiment)
        assert len(jax.local_devices()) >= 2
        src = SyntheticTrace.make(n_functions=10, n_requests=300,
                                  seed=5, utilization=0.25)
        kw = dict(traces=[src], policies=("esff", "sff"),
                  capacities=(3, 5), queue_cap=256, lane_chunk=1)
        one = run_experiment(ExperimentSpec(devices=1, **kw))
        two = run_experiment(ExperimentSpec(devices=2, **kw))
        assert two.meta["n_devices"] == 2
        for k in one.data:
            assert np.array_equal(one.data[k], two.data[k]), k
        print("PARITY_OK")
    """)
    env = dict(os.environ)
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    env["PYTHONPATH"] = (os.path.join(root, "src") + os.pathsep
                         + env.get("PYTHONPATH", ""))
    r = subprocess.run([sys.executable, "-c", script], env=env,
                       cwd=root, capture_output=True, text=True,
                       timeout=600)
    assert r.returncode == 0 and "PARITY_OK" in r.stdout, \
        r.stdout[-2000:] + r.stderr[-3000:]
