"""Theorem 2 (SSFS optimality): property tests vs exhaustive search."""
import math

import pytest

from _hypothesis_compat import given, settings, st

from repro.core import (SSFSFunction, brute_force_best, sequence_cost,
                        ssfs_schedule)


def fns_strategy(max_fns=3, max_reqs=3):
    """Small SSFS instances (brute force is factorial in total requests)."""
    fn = st.tuples(
        st.integers(1, max_reqs),                        # n_j
        st.floats(0.01, 10.0, allow_nan=False),          # exec
        st.floats(0.0, 3.0, allow_nan=False),            # cold
        st.floats(0.0, 3.0, allow_nan=False),            # evict
    )
    return st.lists(fn, min_size=1, max_size=max_fns).map(
        lambda rows: [SSFSFunction(i, n, e, c, v)
                      for i, (n, e, c, v) in enumerate(rows)]
    )


@given(fns_strategy())
@settings(max_examples=60, deadline=None)
def test_weight_order_matches_brute_force(fns):
    total_reqs = sum(f.n for f in fns)
    if total_reqs > 7:          # keep enumeration tractable
        fns = fns[:2]
    _, algo_cost = ssfs_schedule(fns)
    _, best_cost = brute_force_best(fns)
    assert algo_cost == pytest.approx(best_cost, rel=1e-9, abs=1e-9)


@given(fns_strategy(max_fns=4, max_reqs=4))
@settings(max_examples=40, deadline=None)
def test_schedule_cost_consistency(fns):
    """ssfs_schedule's cost equals sequence_cost of its own expansion."""
    order, cost = ssfs_schedule(fns)
    by_id = {f.fn_id: f for f in fns}
    seq = []
    for fid in order:
        seq.extend([fid] * by_id[fid].n)
    assert cost == pytest.approx(sequence_cost(fns, seq), rel=1e-9)


@given(fns_strategy(max_fns=4, max_reqs=4))
@settings(max_examples=40, deadline=None)
def test_contiguity_never_hurts(fns):
    """Splitting a function's batch (paper Fig. 2) never beats contiguous."""
    order, cost = ssfs_schedule(fns)
    by_id = {f.fn_id: f for f in fns}
    if len(fns) < 2 or by_id[order[0]].n < 2:
        return
    # interleave: first function's requests split around the second's
    f0, f1 = order[0], order[1]
    seq = [f0] * (by_id[f0].n - 1) + [f1] * by_id[f1].n + [f0]
    for fid in order[2:]:
        seq.extend([fid] * by_id[fid].n)
    assert sequence_cost(fns, seq) >= cost - 1e-9


def test_paper_weight_formula():
    f = SSFSFunction(0, n=4, exec=2.0, cold=1.0, evict=0.5)
    assert f.weight == pytest.approx(2.0 + 1.5 / 4)


def test_ascending_weight_order():
    fns = [
        SSFSFunction(0, n=1, exec=5.0, cold=1.0, evict=1.0),   # w = 7.0
        SSFSFunction(1, n=10, exec=0.1, cold=1.0, evict=1.0),  # w = 0.3
        SSFSFunction(2, n=2, exec=1.0, cold=0.5, evict=0.5),   # w = 1.5
    ]
    order, _ = ssfs_schedule(fns)
    assert order == [1, 2, 0]
