"""repro.telemetry (PR 10): in-loop event tracing, span reassembly,
the streaming metrics bus and the Perfetto export — the disabled path
must leave every metric bitwise unchanged on every tier, the enabled
path must conserve work (one ARRIVAL per request, one completing EXEC
per done), a traced K=4 churn+retry run must match the Python
reference cluster event-for-event, and the event stream must be
invariant to the engine's cache-window size."""
import json

import numpy as np
import pytest

from repro.api import (ClusterSpec, ExperimentSpec, RetryPolicy,
                       SyntheticTrace, run_experiment)
from repro.telemetry import (TraceKind, TraceRun, assemble_spans,
                             events_summary, save_trace,
                             timeline_to_csv, to_prometheus,
                             validate_trace)
from repro.telemetry.perfetto import load_trace
from repro.telemetry.rail import AUX_FAIL_EXHAUSTED, AUX_FAIL_RETRY

SRC = SyntheticTrace.make(n_functions=12, n_requests=400, seed=3,
                          utilization=0.25)
N = 400
ARR = SRC.arrays()["arrival"]
SPAN = float(ARR.max())
FAULTS = dict(fail_prob=0.2, timeouts=8.0,
              retry=RetryPolicy(max_attempts=3, base=0.05, cap=1.0,
                                jitter=0.3),
              on_overflow="shed", fail_seed=99)
BASE = dict(traces=[SRC], policies=("esff",), capacities=(3,),
            queue_cap=64, stream=True)


def _churn_spec(k=4, router="jsq2"):
    t30 = float(np.quantile(ARR, 0.3))
    t60 = float(np.quantile(ARR, 0.6))
    return ClusterSpec(n_nodes=k, router=router,
                       churn=(((t30, t60),),) + (None,) * (k - 1))


def _assert_bitwise(kw):
    r0 = run_experiment(ExperimentSpec(**kw))
    r1 = run_experiment(ExperimentSpec(**kw, trace_events=True))
    for m in r0.data:
        assert np.array_equal(r0.data[m], r1.data[m],
                              equal_nan=True), m
    assert r1.trace is not None and r0.trace is None
    return r0, r1


# ------------------------------------------------ spec hardening
def test_trace_events_spec_validation():
    with pytest.raises(ValueError, match="host_shard"):
        ExperimentSpec(**BASE, trace_events=True,
                       host_shard=(1, 2)).validate()
    with pytest.raises(ValueError, match="devices"):
        ExperimentSpec(**BASE, trace_events=True,
                       devices=2).validate()
    ExperimentSpec(**BASE, trace_events=True, devices=1).validate()


# ------------------------------- disabled tracing is bitwise free
def test_bitwise_single_node():
    _assert_bitwise(dict(traces=[SRC], policies=("esff", "sff"),
                         capacities=(3, 8), queue_cap=64,
                         stream=True))


def test_bitwise_single_node_exact():
    _assert_bitwise(dict(traces=[SRC], policies=("esff",),
                         capacities=(3,), queue_cap=64, stream=False,
                         keep_per_request=True))


@pytest.mark.parametrize("entry", [
    ClusterSpec(n_nodes=2, router="hash"),       # static tier
    ClusterSpec(n_nodes=2, router="jsq2"),       # dynamic tier
    _churn_spec(),                               # churn rail
])
def test_bitwise_cluster_tiers(entry):
    _assert_bitwise(dict(**BASE, cluster=[entry]))


def test_bitwise_cluster_resilience():
    _assert_bitwise(dict(**BASE, cluster=[_churn_spec()], **FAULTS))


# -------------------------------------- conservation + span model
def test_event_conservation_and_spans():
    r0, r1 = _assert_bitwise(dict(traces=[SRC],
                                  policies=("esff", "sff"),
                                  capacities=(3,), queue_cap=64,
                                  stream=True))
    for pol in ("esff", "sff"):
        ev = r1.trace.events(policy=pol)
        done = int(r0.value("done", policy=pol))
        assert int((ev["kind"] == TraceKind.ARRIVAL).sum()) == N
        assert int((ev["kind"] == TraceKind.EXEC).sum()) == done
        assert int((ev["kind"] == TraceKind.COLD).sum()) == int(
            r0.value("cold_starts", policy=pol))
        spans = r1.trace.spans(policy=pol)
        comp = [s for s in spans.values() if s.completion >= 0]
        assert len(comp) == done
        # span responses reproduce the engine's response-sum metric
        # exactly (the engine's *mean* divides by N, not done)
        np.testing.assert_allclose(
            float(np.sum([s.response for s in comp])),
            float(r0.value("resp_sum", policy=pol)), rtol=1e-9)
        assert all(0 <= s.rid < N and 0 <= s.fn < 12 for s in comp)


def test_static_tier_rid_remap_and_nodes():
    _, r1 = _assert_bitwise(dict(
        **BASE, cluster=[ClusterSpec(n_nodes=3, router="hash")]))
    ev = r1.trace.events()
    # sub-stream-local rids were remapped to global request ids and
    # the per-node sub-streams were patched with their node id
    am = ev["kind"] == TraceKind.ARRIVAL
    assert sorted(ev["rid"][am].tolist()) == list(range(N))
    assert set(np.unique(ev["node"][am]).tolist()) <= {0, 1, 2}
    assert len(set(np.unique(ev["node"][am]).tolist())) == 3


# ------------------------- event-for-event parity vs the reference
def test_reference_parity_churn_retry_k4():
    from repro.cluster.reference import simulate_cluster_reference
    cs = _churn_spec(k=4, router="jsq2")
    rs = run_experiment(ExperimentSpec(**BASE, cluster=[cs],
                                       trace_events=True, **FAULTS))
    ev = rs.trace.events()

    log = []
    ref = simulate_cluster_reference(
        SRC.to_trace(), "esff", cs.validate(), capacity=3,
        queue_cap=64, horizon=SPAN, event_log=log, **FAULTS)
    assert int(rs.value("done")) == ref["done"]
    assert int(rs.value("retried")) == ref["retried"]
    assert len(ev["kind"]) == len(log)

    eng = np.stack([ev["kind"], ev["rid"], ev["fn"], ev["node"]],
                   axis=1).astype(np.int64)
    eng_t = np.asarray(ev["t"], np.float64)
    rlog = np.array([(k, r, f, n) for k, r, f, n, _ in log], np.int64)
    ref_t = np.array([t for *_, t in log], np.float64)

    def order(t, rec):
        return np.lexsort((rec[:, 1], rec[:, 3], rec[:, 0],
                           np.round(t, 9)))

    oe, orf = order(eng_t, eng), order(ref_t, rlog)
    eng, eng_t, rlog, ref_t = eng[oe], eng_t[oe], rlog[orf], ref_t[orf]
    np.testing.assert_array_equal(eng[:, 0], rlog[:, 0],
                                  err_msg="kind")
    np.testing.assert_allclose(eng_t, ref_t, rtol=1e-9, atol=1e-9,
                               err_msg="t")
    np.testing.assert_array_equal(eng[:, 1], rlog[:, 1],
                                  err_msg="rid")
    np.testing.assert_array_equal(eng[:, 2], rlog[:, 2], err_msg="fn")
    m = rlog[:, 3] >= 0    # reference leaves node unset on some kinds
    np.testing.assert_array_equal(eng[m, 3], rlog[m, 3],
                                  err_msg="node")
    # the fault run actually exercised the rails under audit
    kinds = eng[:, 0]
    assert (kinds == TraceKind.RETRY).sum() > 0
    assert (kinds == TraceKind.CHURN).sum() >= 2


# ----------------------------------- window/segment invariance
def test_event_stream_window_invariant():
    kw = dict(**BASE, trace_events=True)
    e1 = run_experiment(
        ExperimentSpec(**kw, window=64)).trace.events()
    e2 = run_experiment(
        ExperimentSpec(**kw, window=256)).trace.events()
    for f in e1:
        np.testing.assert_array_equal(e1[f], e2[f], err_msg=f)


# --------------------------------------- Perfetto JSON round-trip
def test_perfetto_schema_roundtrip(tmp_path):
    rs = run_experiment(ExperimentSpec(**BASE, trace_events=True,
                                       cluster=[_churn_spec()],
                                       **FAULTS))
    ev = rs.trace.events()
    path = tmp_path / "trace.json"
    trace = save_trace(ev, path, label="test")
    n = validate_trace(trace)
    assert n == len(trace["traceEvents"]) > 0
    loaded = load_trace(path)
    assert validate_trace(loaded) == n
    with open(path) as fh:
        raw = json.load(fh)
    assert raw["displayTimeUnit"] == "ms"
    xs = [e for e in raw["traceEvents"] if e["ph"] == "X"]
    ok = ((ev["kind"] == TraceKind.EXEC)
          & ((ev["aux"] & (AUX_FAIL_RETRY | AUX_FAIL_EXHAUSTED)) == 0))
    assert len(xs) == int((ev["kind"] == TraceKind.EXEC).sum())
    assert all(e["dur"] >= 0 for e in xs)
    assert ok.sum() <= len(xs)

    bad = dict(trace, traceEvents=[{"ph": "X", "name": "x"}])
    with pytest.raises(ValueError):
        validate_trace(bad)


# ------------------------------------------- TraceRun persistence
def test_tracerun_npz_roundtrip(tmp_path):
    rs = run_experiment(ExperimentSpec(
        traces=[SRC], policies=("esff", "sff"), capacities=(3,),
        queue_cap=64, stream=True, trace_events=True))
    path = tmp_path / "trace.npz"
    rs.trace.save_npz(path)
    back = TraceRun.load_npz(path)
    assert back.dims == rs.trace.dims
    assert set(back.cells) == set(rs.trace.cells)
    for key, ev in rs.trace.cells.items():
        for f in ev:
            np.testing.assert_array_equal(back.cells[key][f], ev[f])
    assert back.n_events == rs.trace.n_events


# ------------------------------------------------ metrics bus
def test_timeline_metrics_and_exporters(tmp_path):
    rs = run_experiment(ExperimentSpec(**BASE, trace_events=True,
                                       cluster=[ClusterSpec(
                                           n_nodes=2,
                                           router="jsq2")]))
    tl = rs.timeline(bucket=30.0, deadlines=10.0)
    B = len(tl["t"])
    assert tl["arrivals"].shape == (B, 2)
    assert int(tl["arrivals"].sum()) == N
    assert tl["queue_depth"].shape == (B, 2)
    assert np.min(tl["queue_depth"]) >= 0
    assert np.max(tl["queue_depth"]) <= 64   # bounded by queue_cap
    # node depths decompose the global total; warm/busy bounded by
    # per-node slots
    np.testing.assert_allclose(tl["queue_depth"].sum(axis=1),
                               tl["queue_total"])
    assert np.max(tl["busy"]) <= 2 * 3
    assert tl["utilization"].shape == (B, 2)
    assert np.all(tl["utilization"] >= 0)
    # capacity-normalised: a 3-slot node cannot exceed 100% busy
    assert np.all(tl["utilization"] <= 1 + 1e-9)
    thr = float((tl["throughput"] * 30.0).sum())
    assert thr == int(rs.value("done"))
    sr = tl["slo_rolling"]
    assert np.isnan(sr[0]) or 0 <= sr[0] <= 1
    assert 0 <= sr[-1] <= 1

    csv = tmp_path / "tl.csv"
    timeline_to_csv(tl, csv)
    header = csv.read_text().splitlines()[0].split(",")
    assert "queue_depth_k0" in header and "throughput" in header
    assert len(csv.read_text().splitlines()) == B + 1

    ev = rs.trace.events()
    summ = events_summary(ev)
    assert summ["arrivals"] == N
    text = to_prometheus(ev, tl=tl, labels=dict(policy="esff"))
    assert "# TYPE repro_arrivals_total counter" in text
    assert f'repro_arrivals_total{{policy="esff"}} {N}' in text
    assert 'queue_depth{policy="esff",node="1"}' in text


def test_span_assembly_from_raw_events():
    # hand-built stream: arrival -> failed attempt -> retry -> done
    ev = dict(
        kind=np.array([TraceKind.ARRIVAL, TraceKind.EXEC,
                       TraceKind.RETRY, TraceKind.EXEC], np.int32),
        rid=np.array([7, 7, 7, 7], np.int32),
        fn=np.array([2, 2, 2, 2], np.int32),
        node=np.array([0, 0, 0, 1], np.int32),
        aux=np.array([0, AUX_FAIL_RETRY, 0, 0], np.int32),
        qlen=np.zeros(4, np.int32), busy=np.zeros(4, np.int32),
        warm=np.zeros(4, np.int32),
        seq=np.arange(1, 5, dtype=np.int32),
        t=np.array([1.0, 3.0, 3.5, 6.0]),
        dt=np.array([0.0, 2.0, 0.0, 2.0]))
    spans = assemble_spans(ev)
    s = spans[7]
    assert s.arrival == 1.0 and s.completion == 6.0
    assert s.response == 5.0
    assert s.n_attempts == 2 and s.node == 1
    assert s.attempts[0][3] & AUX_FAIL_RETRY
    assert any(k == "RETRY" for k, _, _ in s.children)


# ---------------------------------------------- profiling hooks
def test_profiling_hooks():
    import jax.numpy as jnp

    from repro.telemetry import (PhaseTimer, compile_run_split,
                                 jit_phase_breakdown, provenance,
                                 spec_hash)
    spec = ExperimentSpec(**BASE).validate()
    prov = provenance(spec)
    for k in ("backend", "jax_version", "x64", "spec_hash",
              "trace_events"):
        assert k in prov
    assert prov["spec_hash"] == spec_hash(spec)
    assert prov["trace_events"] is False

    import jax
    f = jax.jit(lambda x: x * 2 + 1)
    c, r, out = compile_run_split(f, jnp.arange(8.0))
    assert c >= 0 and r >= 0
    np.testing.assert_array_equal(np.asarray(out),
                                  np.arange(8.0) * 2 + 1)
    ph = jit_phase_breakdown(f, jnp.arange(8.0))
    assert set(ph) >= {"trace_s", "lower_s", "compile_s", "run_s"}

    pt = PhaseTimer()
    with pt.phase("a"):
        pass
    with pt.phase("b"):
        pass
    rep = pt.report()
    assert set(rep) == {"a", "b"} and all(v >= 0
                                          for v in rep.values())
