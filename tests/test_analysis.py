"""Invariant auditor (`repro.analysis`): HEAD passes every gate, and
seeded regressions — the exact mutations each gate exists to catch —
are caught by that gate and no other.

The mutation fixtures re-introduce, in miniature, real regressions
from the repo's history: an O(N) while-loop carry (pre-PR-2 state
layout), a buffer spelling that makes XLA's copy-insertion charge a
copy per state table per event (pre-PR-6), a loop-body gather over a
multi-row trace operand (the PR-5/6 ~25x XLA:CPU cliff shape), an f32
intermediate (dtype-policy leak), and deprecated-entry-point imports
(the retired regex scan's beat, now AST-level)."""
import os
import textwrap

import pytest

jax = pytest.importorskip("jax")

from repro.analysis.carries import audit_carries           # noqa: E402
from repro.analysis.dtypes import (audit_backoff_jaxpr,    # noqa: E402
                                   audit_boundary_dtypes,
                                   audit_entry_dtypes)
from repro.analysis.entrypoints import (AuditEntry,        # noqa: E402
                                        build_entries)
from repro.analysis.gathers import audit_gathers           # noqa: E402
from repro.analysis.hlo import (audit_copies, audit_f32,   # noqa: E402
                                count_large_copies)
from repro.analysis.lint import (lint_source, scan)        # noqa: E402
from repro.analysis.markers import MARKERS                 # noqa: E402
from repro.core.jax_engine import ensure_x64               # noqa: E402

ensure_x64()

import jax.numpy as jnp                                    # noqa: E402

S = jax.ShapeDtypeStruct


def _entry(name, fn, args, tier="single", allow=()):
    """Wrap an ad-hoc jitted function as an auditable entry."""
    jitted = jax.jit(fn)
    return AuditEntry(name, tier, lambda: jitted.trace(*args),
                      allow=allow)


# ------------------------------------------------------------ fixtures
# Each mutation is the minimal spelling of a real past regression.

def _on_carry_fn(tr, n):
    """O(N) carry: drags an (L, N) table through the while loop."""
    def body(s):
        i, acc = s
        return i + 1, acc + 1.0
    _, acc = jax.lax.while_loop(lambda s: s[0] < n, body,
                                (0, tr * 0.0))
    return acc.sum()


def _rotate_tables_fn(a, b, c, n):
    """Carry-slot rotation: each iteration returns the three (L, F)
    state tables in permuted positions, so no while-body output can
    alias its input buffer — XLA copy-insertion charges a copy per
    table per event, the cost profile PR 6's write-first registers
    eliminated."""
    def body(s):
        i, a, b, c = s
        a = a.at[0, i % MARKERS.F].add(1.0)
        return i + 1, c, a, b
    _, a, b, c = jax.lax.while_loop(lambda s: s[0] < n, body,
                                    (0, a, b, c))
    return a.sum() + b.sum() + c.sum()


def _multirow_gather_fn(tr, n):
    """Per-event gather over the un-flattened (T, N) trace — the
    ~25x XLA:CPU generic-gather cliff shape."""
    def body(s):
        i, acc = s
        col = tr[:, i]                    # gather, operand (T, N)
        return i + 1, acc + col.sum()
    _, acc = jax.lax.while_loop(lambda s: s[0] < n, body, (0, 0.0))
    return acc


def _f32_leak_fn(x):
    return (x.astype(jnp.float32) * jnp.float32(2.0)).sum()


_TR = S((MARKERS.L, MARKERS.N), jnp.float64)
_TR2 = S((MARKERS.T, MARKERS.N), jnp.float64)
_TBL = S((MARKERS.L, MARKERS.F), jnp.float64)
_I = S((), jnp.int32)


@pytest.fixture(scope="module")
def head_traced():
    """Every audited HEAD entry, traced once (abstract args — no
    events execute)."""
    entries = build_entries()
    return [(e, e.trace()) for e in entries]


# -------------------------------------------------- HEAD passes gates
def test_head_entries_cover_every_variant(head_traced):
    names = {e.name for e, _ in head_traced}
    for expected in ("single_stream", "single_exact", "single_resil",
                     "cluster_stream", "cluster_churn",
                     "cluster_resil", "cluster_exact_delay"):
        assert expected in names


def test_head_passes_carry_budget(head_traced):
    for entry, traced in head_traced:
        res = audit_carries(entry, traced)
        assert res["passed"], res["problems"]
        assert res["loops"], f"{entry.name}: no loops audited"


def test_head_passes_gather_cliff(head_traced):
    for entry, traced in head_traced:
        res = audit_gathers(entry, traced)
        assert res["passed"], res["problems"]
        assert res["loop_gathers_checked"] > 0, (
            f"{entry.name}: gather audit saw no loop reads at all — "
            f"detector or tracing regressed")


def test_head_passes_dtype_policy(head_traced):
    for entry, traced in head_traced:
        res = audit_entry_dtypes(entry, traced)
        assert res["passed"], res["problems"]


def test_head_dynamic_loop_within_copy_budget(head_traced):
    """The PR-6-verified bound: <= 2 table-scale copies per event step
    in the dynamic cluster loop's optimized HLO."""
    entry, traced = next((e, t) for e, t in head_traced
                         if e.name == "cluster_stream")
    hlo = traced.lower().compile().as_text()
    res = audit_copies(entry.name, hlo, MARKERS,
                       budget=entry.copy_budget)
    assert res["passed"], res["problems"]
    assert res["measured"]["while_bodies"] > 0
    f32 = audit_f32(entry.name, hlo)
    assert f32["passed"], f32["problems"]


def test_head_passes_boundary_and_backoff_dtypes():
    res = audit_boundary_dtypes()
    assert res["passed"], res["problems"]
    res = audit_backoff_jaxpr()
    assert res["passed"], res["problems"]
    assert res["out_dtype"] == "float64"


def test_head_repo_tree_passes_lint(capsys):
    assert scan() == 0


# ------------------------------------------- seeded regressions caught
def test_on_carry_caught_by_carry_gate_only():
    e = _entry("mut_on_carry", _on_carry_fn, (_TR, _I))
    traced = e.trace()
    res = audit_carries(e, traced)
    assert not res["passed"]
    assert any("scale with the trace length N" in p
               for p in res["problems"])
    # ...and by that gate only: the fixture has no loop gathers or
    # narrow floats, so the sibling analyzers stay quiet.
    assert audit_gathers(e, traced)["passed"]
    assert audit_entry_dtypes(e, traced)["passed"]


def test_missing_documented_rail_also_fails():
    """The allowlist is an exact multiset: a rail that disappears is
    as loud as one that appears (the documented layout changed)."""
    e = _entry("mut_missing_rail",
               lambda n: jax.lax.while_loop(
                   lambda s: s[0] < n,
                   lambda s: (s[0] + 1, s[1] + 1.0), (0, 0.0))[1],
               (_I,), allow=("start",))
    res = audit_carries(e, e.trace())
    assert not res["passed"]
    assert any("found none" in p for p in res["problems"])


def test_table_rotation_caught_by_copy_gate_only():
    e = _entry("mut_rotate", _rotate_tables_fn,
               (_TBL, _TBL, _TBL, _I))
    traced = e.trace()
    hlo = traced.lower().compile().as_text()
    counts = count_large_copies(hlo, MARKERS)
    assert counts["max_large_copies_per_body"] > 2, counts
    res = audit_copies(e.name, hlo, MARKERS, budget=2)
    assert not res["passed"]
    assert any("write-first" in p for p in res["problems"])
    # (L, F) tables don't scale with N and nothing gathers: the carry
    # and gather gates pass this fixture.
    assert audit_carries(e, traced)["passed"]
    assert audit_gathers(e, traced)["passed"]


def test_copy_gate_never_passes_without_a_loop():
    """A parser regression (or a loop-free program) must fail loudly,
    not pass vacuously."""
    res = audit_copies("mut_no_loop", "ENTRY %main () -> f64[] {\n}\n",
                       MARKERS, budget=2)
    assert not res["passed"]
    assert any("no while-loop body" in p for p in res["problems"])


def test_multirow_gather_caught_by_gather_gate_only():
    e = _entry("mut_gather", _multirow_gather_fn, (_TR2, _I))
    traced = e.trace()
    res = audit_gathers(e, traced)
    assert not res["passed"]
    assert any("generic-gather cliff" in p for p in res["problems"])
    assert audit_carries(e, traced)["passed"]
    assert audit_entry_dtypes(e, traced)["passed"]


def test_flattened_gather_is_sanctioned():
    """The engines' actual spelling — rank-1 gather over the (T*N,)
    flattened view — must stay clean."""
    flat = S((MARKERS.T * MARKERS.N,), jnp.float64)

    def fn(tr, n):
        def body(s):
            i, acc = s
            return i + 1, acc + tr[i]
        return jax.lax.while_loop(lambda s: s[0] < n, body,
                                  (0, 0.0))[1]

    e = _entry("flat_gather", fn, (flat, _I))
    res = audit_gathers(e, e.trace())
    assert res["passed"], res["problems"]
    assert res["loop_gathers_checked"] > 0


def test_f32_leak_caught_by_dtype_gate_only():
    e = _entry("mut_f32", _f32_leak_fn, (_TR,))
    traced = e.trace()
    res = audit_entry_dtypes(e, traced)
    assert not res["passed"]
    assert any("narrow float" in p for p in res["problems"])
    assert audit_carries(e, traced)["passed"]
    assert audit_gathers(e, traced)["passed"]


def test_f32_hlo_scan_catches_compiled_leak():
    res = audit_f32("mut_f32_hlo",
                    "%x = f32[3,769]{1,0} convert(%y)\n")
    assert not res["passed"]
    assert res["f32_tensors"] == 1


# ----------------------------------------------------------- AST lint
def test_lint_flags_each_retired_entry_point():
    src = textwrap.dedent("""\
        from repro.core.jax_engine import sweep
        import os
        path = os.environ.get("REPRO_AZURE_NPZ")
        def run(engine):
            return engine.jax_engine.sweep(path)
    """)
    reasons = [r for _, r in lint_source(src, is_benchmark=False)]
    assert "imports sweep from jax_engine" in reasons
    assert any("REPRO_AZURE_NPZ" in r for r in reasons)
    assert "calls jax_engine.sweep()" in reasons


def test_lint_is_ast_level_not_textual():
    """Prose can't trip it; a reformatted import can't dodge it."""
    prose = ('"""Discussion of repro.core.jax_engine and its sweep '
             'helper, plus the REPRO_AZURE_NPZ era."""\n')
    assert lint_source(prose, is_benchmark=False) == []
    dodged = ("from repro.core.jax_engine import (\n"
              "    simulate,\n    sweep,\n)\n")
    assert lint_source(dodged, is_benchmark=False)


def test_lint_py_engine_rule_is_benchmarks_only():
    src = "from repro.core import simulate\n"
    assert lint_source(src, is_benchmark=True)
    assert lint_source(src, is_benchmark=False) == []
    assert lint_source(src, is_benchmark=True,
                       py_engine_exempt=True) == []


def test_lint_scan_walks_tree_and_honours_allowlist(tmp_path, capsys):
    bench = tmp_path / "benchmarks"
    bench.mkdir()
    (bench / "bad.py").write_text(
        "from repro.core.simulator import EventSim\n")
    # same content at an allowlisted path -> exempt
    (bench / "sim_throughput.py").write_text(
        "from repro.core.simulator import EventSim\n")
    srcdir = tmp_path / "src"
    srcdir.mkdir()
    (srcdir / "ok.py").write_text("from repro.api import run\n")
    assert scan(str(tmp_path)) == 1
    err = capsys.readouterr().err
    assert "DEPRECATED ENTRY POINT: " + os.path.join(
        "benchmarks", "bad.py") in err
    assert "sim_throughput" not in err


# --------------------------------------------------------- CLI surface
def test_cli_quick_runs_lint_gate(tmp_path, capsys):
    from repro.analysis.__main__ import main
    out = tmp_path / "report.json"
    rc = main(["--gates", "deprecation_lint", "--out", str(out)])
    assert rc == 0
    import json
    report = json.loads(out.read_text())
    assert report["passed"]
    assert set(report["gates"]) == {"deprecation_lint"}
    assert report["schema"] == 1


# ------------------------------------------------ telemetry lowering
def test_telemetry_gate_catches_callback_leak():
    """Seed: an untraced compiled HLO that leaked the trace rail's
    io_callback must fail the telemetry_lowering gate; a clean text
    passes and the positive traced-jaxpr checks hold on HEAD."""
    from repro.analysis.telemetry_gate import audit_telemetry
    checks = audit_telemetry({
        "clean": "HloModule m\nwhile.body { add } ",
        "leaky": ("HloModule m\ncustom-call(), "
                  "custom_call_target=\"xla_python_cpu_callback\""),
    })
    by = {c["name"]: c for c in checks}
    assert by["clean:untraced_hlo"]["passed"]
    assert not by["leaky:untraced_hlo"]["passed"]
    assert by["leaky:untraced_hlo"]["problems"]
    # gate is not vacuous: trace=True builds do contain the callback
    assert by["single_stream:traced_jaxpr"]["passed"]
    assert by["cluster_stream:traced_jaxpr"]["passed"]
