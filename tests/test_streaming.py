"""Streaming-metrics mode (`repro.core.jax_engine`): equivalence with
the exact per-request mode, positional-queue behaviour under deep
backlogs, cache-window bitwise invariance (including queue links and
timers spanning window boundaries), backend-adaptive lane batching,
the minute-binned timeline fold, and the columnar trace fast path."""
import numpy as np
import pytest

from repro.core import simulate
from repro.core.jax_engine import (HIST_PER_DECADE, hist_edges,
                                   resolve_lane_chunk,
                                   simulate_policy_from_trace,
                                   simulate_policy_jax, sweep)
from repro.traces import (synth_azure_arrays, synth_azure_trace,
                          trace_from_lists)

POLICIES = ("esff", "sff", "openwhisk", "faascache")
BIN_RATIO = 10.0 ** (1.0 / HIST_PER_DECADE)


def test_stream_vs_exact_equivalence():
    """Means bitwise-equal (identical fold path), p99 within one
    histogram bin, across >= 3 policies and two capacities."""
    tr = synth_azure_trace(n_functions=20, n_requests=600,
                           utilization=0.25, seed=21)
    exact = sweep(tr, policies=POLICIES, capacities=(4, 8),
                  queue_cap=256, stream=False)
    strm = sweep(tr, policies=POLICIES, capacities=(4, 8),
                 queue_cap=256, stream=True)
    assert int(strm["overflow"].sum()) == 0
    assert int(strm["stalled"].sum()) == 0
    assert np.array_equal(strm["mean_response"],
                          exact["mean_response"])
    assert np.array_equal(strm["mean_slowdown"],
                          exact["mean_slowdown"])
    assert np.all(strm["p99_response"]
                  <= exact["p99_response"] * BIN_RATIO + 1e-12)
    assert np.all(strm["p99_response"]
                  >= exact["p99_response"] / BIN_RATIO - 1e-12)


def test_stream_accumulators_match_per_request_records():
    """The folded accumulators agree with recomputing the metrics from
    the exact mode's per-request arrays; the histogram counts every
    completed request exactly once."""
    tr = synth_azure_trace(n_functions=15, n_requests=500,
                           utilization=0.3, seed=8)
    a = tr.to_arrays()
    import jax.numpy as jnp
    args = (jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]))
    kw = dict(policy="sff", n_fns=tr.n_functions, capacity=8,
              queue_cap=256)
    ex = simulate_policy_jax(*args, stream=False, **kw)
    st = simulate_policy_jax(*args, stream=True, **kw)
    assert "completion" not in st          # O(N) outputs really gone
    n = len(tr)
    assert int(np.asarray(st["resp_hist"]).sum()) == n
    resp = np.asarray(ex["completion"]) - a["arrival"]
    np.testing.assert_allclose(float(st["resp_sum"]) / n, resp.mean(),
                               rtol=1e-12)
    assert float(st["max_response"]) == pytest.approx(resp.max(),
                                                      rel=1e-12)
    # both modes fold identically -> bitwise-equal accumulators
    assert float(st["resp_sum"]) == float(ex["resp_sum"])
    assert float(st["slow_sum"]) == float(ex["slow_sum"])


def test_positional_queues_survive_starvation():
    """SFF starves long functions, so a request can stay queued for
    most of the trace — the positional queues (cursors into the
    loop-invariant arrival order) must reproduce the Python engine
    exactly even then."""
    tr = synth_azure_trace(n_functions=20, n_requests=2000,
                           utilization=0.25, seed=4)
    py = simulate(tr, "sff", capacity=8)
    jx = simulate_policy_from_trace(tr, "sff", 8, queue_cap=2048)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)


def test_hist_edges_shape():
    edges = hist_edges()
    assert len(edges) == 65
    assert edges[HIST_PER_DECADE] / edges[0] == pytest.approx(10.0)


def test_saturated_histogram_reports_true_tail():
    """Responses past the histogram's top edge (1e4 s) land in the
    last bin; the streamed p99 must fall back to the exact carried
    maximum instead of silently capping at the bin edge."""
    n = 8
    tr = trace_from_lists(
        fn_ids=[0] * n,
        arrivals=[float(i) for i in range(n)],
        exec_times=[20_000.0] * n,     # every response > 1e4 s
        cold=[0.5], evict=[0.2])
    out = sweep(tr, policies=("openwhisk",), capacities=(1,),
                queue_cap=64, stream=True)
    assert int(out["overflow"].sum()) == 0
    assert int(out["stalled"].sum()) == 0
    p99 = float(out["p99_response"][0, 0, 0, 0])
    assert p99 > 2e4                   # not capped at hist_edges()[-1]
    assert p99 == float(out["max_response"][0, 0, 0, 0])


def test_under_range_histogram_reports_true_tail():
    """All-fast traces (every response below the 1e-4 s floor) must
    not report the floor edge as p99 — the carried max clamps it."""
    n = 8
    tr = trace_from_lists(
        fn_ids=[0] * n,
        arrivals=[float(i) for i in range(n)],
        exec_times=[1e-5] * n,
        cold=[0.0], evict=[0.0])
    out = sweep(tr, policies=("openwhisk",), capacities=(1,),
                queue_cap=64, stream=True)
    assert int(out["stalled"].sum()) == 0
    p99 = float(out["p99_response"][0, 0, 0, 0])
    assert p99 == float(out["max_response"][0, 0, 0, 0])
    assert p99 < 2e-5                  # not the 1.33e-4 floor edge


BITWISE_KEYS = ("mean_response", "mean_slowdown", "p99_response",
                "max_response", "resp_hist", "cold_starts",
                "evictions", "overflow", "stalled")


def _assert_bitwise(a, b):
    for k in BITWISE_KEYS:
        np.testing.assert_array_equal(np.asarray(a[k]),
                                      np.asarray(b[k]), err_msg=k)


def test_window_boundary_bitwise_invariance():
    """A window size that splits a busy queue mid-window must not move
    a single bit of the streamed metrics: queue links spanning the
    boundary fall back to the full positional operand, and the
    per-event metric fold is order-identical at any window size. SFF
    starves long functions, so backlogged entries really do cross
    every boundary of a 64-request window here."""
    tr = synth_azure_trace(n_functions=16, n_requests=900,
                           utilization=0.45, seed=11)
    kw = dict(policies=("sff",), capacities=(6,), queue_cap=1024)
    ref = sweep(tr, stream=True, window=10**9, **kw)   # single window
    assert int(ref["stalled"].sum()) == 0
    win = sweep(tr, stream=True, window=64, **kw)
    _assert_bitwise(win, ref)
    # ... and the exact mode through the same small windows agrees
    # bitwise with the streamed mode (the shared per-event fold)
    exact = sweep(tr, stream=False, window=64, **kw)
    assert np.array_equal(win["mean_response"],
                          exact["mean_response"])
    assert np.array_equal(win["mean_slowdown"],
                          exact["mean_slowdown"])


def test_windowed_exact_mode_matches_python_under_starvation():
    """Exact per-request parity with the Python event engine when the
    windows are far smaller than the starved backlog."""
    tr = synth_azure_trace(n_functions=20, n_requests=1000,
                           utilization=0.3, seed=4)
    py = simulate(tr, "sff", capacity=8)
    jx = simulate_policy_from_trace(tr, "sff", 8, queue_cap=2048,
                                    window=101)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)


def test_owv2_timer_fires_across_window_boundary():
    """An openwhisk_v2 head-wait timer armed in one window and firing
    after the arrival cursor has moved to the next window (its rail
    reads then cross the slab boundary) must reproduce the Python
    policy exactly, and streamed metrics must stay bitwise equal to
    the unwindowed run."""
    # capacity 1; f0 holds the slot while f1 arrivals queue right at
    # the window-4 boundary: r3 (t=0.30, window 0) arms a timer for
    # t=0.40, which fires after r4 (t=0.35, window 1) has arrived
    fn_ids = [0, 1, 1, 1, 1, 1, 0, 1]
    arrivals = [0.0, 0.10, 0.20, 0.30, 0.35, 0.45, 3.0, 3.5]
    execs = [2.0, 0.05, 0.05, 0.05, 0.05, 0.05, 0.2, 0.05]
    tr = trace_from_lists(fn_ids, arrivals, execs,
                          cold=[0.4, 0.3], evict=[0.2, 0.1])
    py = simulate(tr, "openwhisk_v2", capacity=1)
    jx = simulate_policy_from_trace(tr, "openwhisk_v2", 1,
                                    queue_cap=64, window=4)
    assert int(jx["stalled"]) == 0
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)
    kw = dict(policies=("openwhisk_v2",), capacities=(1,),
              queue_cap=64)
    _assert_bitwise(sweep(tr, stream=True, window=4, **kw),
                    sweep(tr, stream=True, window=10**9, **kw))


def test_lane_chunk_settings_do_not_change_results():
    """Sweep results are invariant to how lanes are batched into
    device calls: chunk sizes 1 and 16 and the ``auto`` probe must
    agree exactly on a small policy x capacity grid."""
    tr = synth_azure_trace(n_functions=12, n_requests=400,
                           utilization=0.25, seed=3)
    kw = dict(policies=("esff", "sff"), capacities=(4, 6),
              queue_cap=512, stream=True)
    ref = sweep(tr, lane_chunk=16, **kw)
    for setting in (1, "auto"):
        out = sweep(tr, lane_chunk=setting, **kw)
        _assert_bitwise(out, ref)


def test_resolve_lane_chunk_auto_probe_is_cached():
    c1 = resolve_lane_chunk("auto")
    assert isinstance(c1, int) and c1 >= 1
    assert resolve_lane_chunk("auto") == c1      # cached, no re-probe
    assert resolve_lane_chunk(7) == 7
    assert resolve_lane_chunk("") >= 1           # backend table


def test_timeline_fold_matches_python_timeline():
    """The engine's minute-binned accumulator reproduces the Python
    engine's Fig. 8 timeline (same bins, counts, and means)."""
    tr = synth_azure_trace(n_functions=12, n_requests=400,
                           utilization=0.25, seed=3)
    a = tr.to_arrays()
    n_bins = int(a["arrival"].max() // 60.0) + 1
    out = sweep(tr, policies=("esff",), capacities=(6,),
                queue_cap=512, stream=True, tl_bins=n_bins,
                tl_bucket=60.0)
    assert int(out["stalled"].sum()) == 0
    cnt = np.asarray(out["tl_count"][0, 0, 0, 0], np.int64)
    rsum = np.asarray(out["tl_resp_sum"][0, 0, 0, 0])
    esum = np.asarray(out["tl_exec_sum"][0, 0, 0, 0])
    assert int(cnt.sum()) == len(tr)
    res = simulate(tr, "esff", capacity=6)
    tl = res.timeline(60.0)
    n_py = len(tl["minute"])
    np.testing.assert_array_equal(cnt[:n_py], tl["n_requests"])
    nz = cnt[:n_py] > 0
    np.testing.assert_allclose(
        (rsum[:n_py][nz] / cnt[:n_py][nz]), tl["mean_response"][nz],
        rtol=1e-12)
    np.testing.assert_allclose(
        (esum[:n_py][nz] / cnt[:n_py][nz]), tl["mean_exec"][nz],
        rtol=1e-12)


def test_synth_azure_arrays_matches_trace_path():
    tr = synth_azure_trace(n_functions=10, n_requests=300, seed=5)
    a = tr.to_arrays()
    b = synth_azure_arrays(n_functions=10, n_requests=300, seed=5)
    for k in ("fn_id", "arrival", "exec_time", "cold_start", "evict"):
        np.testing.assert_array_equal(a[k], b[k])


@pytest.mark.slow
def test_large_trace_parity_with_python_engine():
    """10^5-request spot check: the streaming engine (bounded carried
    state) agrees with the Python event engine end to end."""
    tr = synth_azure_trace(n_functions=100, n_requests=100_000,
                           utilization=0.2, seed=7)
    py = simulate(tr, "esff", capacity=16)
    jx = simulate_policy_from_trace(tr, "esff", 16, queue_cap=4096)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)
    st = sweep(tr, policies=("esff",), capacities=(16,),
               queue_cap=4096, stream=True)
    np.testing.assert_allclose(st["mean_response"][0, 0, 0, 0],
                               py.mean_response, rtol=1e-9)
