"""Streaming-metrics mode (`repro.core.jax_engine`): equivalence with
the exact per-request mode, positional-queue behaviour under deep
backlogs, and the columnar trace fast path."""
import numpy as np
import pytest

from repro.core import simulate
from repro.core.jax_engine import (HIST_PER_DECADE, hist_edges,
                                   simulate_policy_from_trace,
                                   simulate_policy_jax, sweep)
from repro.traces import (synth_azure_arrays, synth_azure_trace,
                          trace_from_lists)

POLICIES = ("esff", "sff", "openwhisk", "faascache")
BIN_RATIO = 10.0 ** (1.0 / HIST_PER_DECADE)


def test_stream_vs_exact_equivalence():
    """Means bitwise-equal (identical fold path), p99 within one
    histogram bin, across >= 3 policies and two capacities."""
    tr = synth_azure_trace(n_functions=20, n_requests=600,
                           utilization=0.25, seed=21)
    exact = sweep(tr, policies=POLICIES, capacities=(4, 8),
                  queue_cap=256, stream=False)
    strm = sweep(tr, policies=POLICIES, capacities=(4, 8),
                 queue_cap=256, stream=True)
    assert int(strm["overflow"].sum()) == 0
    assert int(strm["stalled"].sum()) == 0
    assert np.array_equal(strm["mean_response"],
                          exact["mean_response"])
    assert np.array_equal(strm["mean_slowdown"],
                          exact["mean_slowdown"])
    assert np.all(strm["p99_response"]
                  <= exact["p99_response"] * BIN_RATIO + 1e-12)
    assert np.all(strm["p99_response"]
                  >= exact["p99_response"] / BIN_RATIO - 1e-12)


def test_stream_accumulators_match_per_request_records():
    """The folded accumulators agree with recomputing the metrics from
    the exact mode's per-request arrays; the histogram counts every
    completed request exactly once."""
    tr = synth_azure_trace(n_functions=15, n_requests=500,
                           utilization=0.3, seed=8)
    a = tr.to_arrays()
    import jax.numpy as jnp
    args = (jnp.asarray(a["fn_id"]), jnp.asarray(a["arrival"]),
            jnp.asarray(a["exec_time"]), jnp.asarray(a["cold_start"]),
            jnp.asarray(a["evict"]))
    kw = dict(policy="sff", n_fns=tr.n_functions, capacity=8,
              queue_cap=256)
    ex = simulate_policy_jax(*args, stream=False, **kw)
    st = simulate_policy_jax(*args, stream=True, **kw)
    assert "completion" not in st          # O(N) outputs really gone
    n = len(tr)
    assert int(np.asarray(st["resp_hist"]).sum()) == n
    resp = np.asarray(ex["completion"]) - a["arrival"]
    np.testing.assert_allclose(float(st["resp_sum"]) / n, resp.mean(),
                               rtol=1e-12)
    assert float(st["max_response"]) == pytest.approx(resp.max(),
                                                      rel=1e-12)
    # both modes fold identically -> bitwise-equal accumulators
    assert float(st["resp_sum"]) == float(ex["resp_sum"])
    assert float(st["slow_sum"]) == float(ex["slow_sum"])


def test_positional_queues_survive_starvation():
    """SFF starves long functions, so a request can stay queued for
    most of the trace — the positional queues (cursors into the
    loop-invariant arrival order) must reproduce the Python engine
    exactly even then."""
    tr = synth_azure_trace(n_functions=20, n_requests=2000,
                           utilization=0.25, seed=4)
    py = simulate(tr, "sff", capacity=8)
    jx = simulate_policy_from_trace(tr, "sff", 8, queue_cap=2048)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)


def test_hist_edges_shape():
    edges = hist_edges()
    assert len(edges) == 65
    assert edges[HIST_PER_DECADE] / edges[0] == pytest.approx(10.0)


def test_saturated_histogram_reports_true_tail():
    """Responses past the histogram's top edge (1e4 s) land in the
    last bin; the streamed p99 must fall back to the exact carried
    maximum instead of silently capping at the bin edge."""
    n = 8
    tr = trace_from_lists(
        fn_ids=[0] * n,
        arrivals=[float(i) for i in range(n)],
        exec_times=[20_000.0] * n,     # every response > 1e4 s
        cold=[0.5], evict=[0.2])
    out = sweep(tr, policies=("openwhisk",), capacities=(1,),
                queue_cap=64, stream=True)
    assert int(out["overflow"].sum()) == 0
    assert int(out["stalled"].sum()) == 0
    p99 = float(out["p99_response"][0, 0, 0, 0])
    assert p99 > 2e4                   # not capped at hist_edges()[-1]
    assert p99 == float(out["max_response"][0, 0, 0, 0])


def test_under_range_histogram_reports_true_tail():
    """All-fast traces (every response below the 1e-4 s floor) must
    not report the floor edge as p99 — the carried max clamps it."""
    n = 8
    tr = trace_from_lists(
        fn_ids=[0] * n,
        arrivals=[float(i) for i in range(n)],
        exec_times=[1e-5] * n,
        cold=[0.0], evict=[0.0])
    out = sweep(tr, policies=("openwhisk",), capacities=(1,),
                queue_cap=64, stream=True)
    assert int(out["stalled"].sum()) == 0
    p99 = float(out["p99_response"][0, 0, 0, 0])
    assert p99 == float(out["max_response"][0, 0, 0, 0])
    assert p99 < 2e-5                  # not the 1.33e-4 floor edge


def test_synth_azure_arrays_matches_trace_path():
    tr = synth_azure_trace(n_functions=10, n_requests=300, seed=5)
    a = tr.to_arrays()
    b = synth_azure_arrays(n_functions=10, n_requests=300, seed=5)
    for k in ("fn_id", "arrival", "exec_time", "cold_start", "evict"):
        np.testing.assert_array_equal(a[k], b[k])


@pytest.mark.slow
def test_large_trace_parity_with_python_engine():
    """10^5-request spot check: the streaming engine (bounded carried
    state) agrees with the Python event engine end to end."""
    tr = synth_azure_trace(n_functions=100, n_requests=100_000,
                           utilization=0.2, seed=7)
    py = simulate(tr, "esff", capacity=16)
    jx = simulate_policy_from_trace(tr, "esff", 16, queue_cap=4096)
    assert int(jx["overflow"]) == 0
    assert int(jx["stalled"]) == 0
    assert int(jx["cold_starts"]) == py.server.cold_starts
    resp_py = np.array([r.response for r in tr.requests])
    np.testing.assert_allclose(jx["response"], resp_py, rtol=1e-9,
                               atol=1e-9)
    st = sweep(tr, policies=("esff",), capacities=(16,),
               queue_cap=4096, stream=True)
    np.testing.assert_allclose(st["mean_response"][0, 0, 0, 0],
                               py.mean_response, rtol=1e-9)
