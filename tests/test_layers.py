"""Layer-level correctness: chunked attention vs naive softmax, SSD
chunked vs token recurrence, MoE capacity vs dense oracle, rope, norms."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.models import layers as L
from repro.models import mamba as M
from repro.models.config import ModelConfig


def naive_attention(q, k, v, causal=True, q_offset=0, window=None):
    B, Sq, H, D = q.shape
    _, Skv, KVH, _ = k.shape
    g = H // KVH
    kf = jnp.repeat(k, g, axis=2).astype(jnp.float32)
    vf = jnp.repeat(v, g, axis=2).astype(jnp.float32)
    s = jnp.einsum("bshd,bthd->bhst", q.astype(jnp.float32), kf)
    s = s / math.sqrt(D)
    if causal:
        aq = jnp.arange(Sq) + q_offset
        ak = jnp.arange(Skv)
        mask = aq[:, None] >= ak[None, :]
        if window is not None:
            mask &= (aq[:, None] - ak[None, :]) <= window
        s = jnp.where(mask[None, None], s, -jnp.inf)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhst,bthd->bshd", p, vf)
    return o.astype(q.dtype)


@pytest.mark.parametrize("Sq,Skv,H,KVH,chunk,offset,window", [
    (32, 32, 4, 4, 8, 0, None),
    (32, 32, 4, 2, 8, 0, None),
    (16, 48, 4, 1, 16, 32, None),     # decode-continuation style
    (64, 64, 2, 2, 16, 0, 24),        # sliding window
    (33, 50, 4, 2, 16, 0, None),      # ragged (padding paths)
])
def test_chunked_attention_matches_naive(Sq, Skv, H, KVH, chunk, offset,
                                         window):
    rng = np.random.default_rng(0)
    D = 16
    q = jnp.array(rng.normal(size=(2, Sq, H, D)), jnp.float32)
    k = jnp.array(rng.normal(size=(2, Skv, KVH, D)), jnp.float32)
    v = jnp.array(rng.normal(size=(2, Skv, KVH, D)), jnp.float32)
    if offset % max(chunk, 1) != 0:
        pytest.skip("offset must be chunk aligned")
    got = L.chunked_attention(q, k, v, chunk=chunk, q_offset=offset,
                              window=window)
    want = naive_attention(q, k, v, q_offset=offset, window=window)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_noncausal():
    rng = np.random.default_rng(1)
    q = jnp.array(rng.normal(size=(1, 24, 2, 8)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 40, 2, 8)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 40, 2, 8)), jnp.float32)
    got = L.chunked_attention(q, k, v, chunk=16, causal=False)
    want = naive_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


def test_chunked_attention_grads_finite():
    rng = np.random.default_rng(2)
    q = jnp.array(rng.normal(size=(1, 32, 2, 8)), jnp.float32)
    k = jnp.array(rng.normal(size=(1, 32, 1, 8)), jnp.float32)
    v = jnp.array(rng.normal(size=(1, 32, 1, 8)), jnp.float32)
    g = jax.grad(lambda q: L.chunked_attention(q, k, v, chunk=8).sum())(q)
    assert jnp.isfinite(g).all()


# ------------------------------------------------------------------ SSD
@pytest.mark.parametrize("Lq,chunk,h,p,g,n", [
    (64, 16, 4, 8, 1, 16),
    (50, 16, 4, 8, 2, 8),       # ragged length + groups
    (32, 32, 2, 4, 1, 4),       # single chunk
])
def test_ssd_chunked_matches_reference(Lq, chunk, h, p, g, n):
    rng = np.random.default_rng(0)
    b = 2
    x = jnp.array(rng.normal(size=(b, Lq, h, p)), jnp.float32)
    dt = jnp.array(rng.uniform(0.01, 0.2, size=(b, Lq, h)), jnp.float32)
    A = -jnp.array(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = jnp.array(rng.normal(size=(b, Lq, g, n)), jnp.float32)
    C = jnp.array(rng.normal(size=(b, Lq, g, n)), jnp.float32)
    y1, s1 = M.ssd_chunked(x, dt, A, B, C, chunk=chunk)
    y2, s2 = M.ssd_reference(x, dt, A, B, C)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=2e-4, atol=2e-4)


def test_ssd_state_carry():
    """Chunked SSD with an initial state == reference run over the
    concatenated sequence."""
    rng = np.random.default_rng(3)
    b, l1, l2, h, p, g, n = 1, 32, 32, 2, 4, 1, 8
    mk = lambda s: jnp.array(rng.normal(size=s), jnp.float32)
    x = mk((b, l1 + l2, h, p))
    dt = jnp.array(rng.uniform(0.01, 0.2, size=(b, l1 + l2, h)), jnp.float32)
    A = -jnp.array(rng.uniform(0.5, 2.0, size=(h,)), jnp.float32)
    B = mk((b, l1 + l2, g, n))
    C = mk((b, l1 + l2, g, n))
    y_all, s_all = M.ssd_reference(x, dt, A, B, C)
    _, s1 = M.ssd_chunked(x[:, :l1], dt[:, :l1], A, B[:, :l1], C[:, :l1],
                          chunk=16)
    y2, s2 = M.ssd_chunked(x[:, l1:], dt[:, l1:], A, B[:, l1:], C[:, l1:],
                           chunk=16, init_state=s1)
    np.testing.assert_allclose(np.asarray(y2), np.asarray(y_all[:, l1:]),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_all),
                               rtol=2e-4, atol=2e-4)


def test_ssd_grads_finite():
    rng = np.random.default_rng(4)
    b, l, h, p, g, n = 1, 32, 2, 4, 1, 8
    x = jnp.array(rng.normal(size=(b, l, h, p)), jnp.float32)
    dt = jnp.array(rng.uniform(0.01, 0.2, size=(b, l, h)), jnp.float32)
    A = -jnp.ones((h,), jnp.float32)
    B = jnp.array(rng.normal(size=(b, l, g, n)), jnp.float32)
    C = jnp.array(rng.normal(size=(b, l, g, n)), jnp.float32)
    gr = jax.grad(lambda x: M.ssd_chunked(x, dt, A, B, C, chunk=8)[0].sum())(x)
    assert jnp.isfinite(gr).all()


# ------------------------------------------------------------------ MoE
def _moe_cfg(**kw):
    base = dict(n_experts=8, topk=2, moe_d_ff=32, d_model=16,
                capacity_factor=8.0, n_shared_experts=0,
                param_dtype="float32", compute_dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def test_moe_capacity_matches_dense_when_uncapped():
    cfg = _moe_cfg()
    ps = L.ParamSet(jax.random.key(0), jnp.float32)
    L.init_moe(ps, cfg)
    params, _ = ps.done()
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(2, 12, cfg.d_model)), jnp.float32)
    ident = lambda a, ax: a
    y_dense, aux_d = L.moe_apply_dense(params, cfg, x, ident)
    capacity = 2 * 12 * cfg.topk  # uncapped
    y_cap, aux_c = L.moe_apply_capacity(params, cfg, x, ident, capacity)
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_cap),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-5)


def test_moe_capacity_drops_overflow():
    cfg = _moe_cfg()
    ps = L.ParamSet(jax.random.key(0), jnp.float32)
    L.init_moe(ps, cfg)
    params, _ = ps.done()
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(1, 64, cfg.d_model)), jnp.float32)
    ident = lambda a, ax: a
    y_small, _ = L.moe_apply_capacity(params, cfg, x, ident, capacity=2)
    y_big, _ = L.moe_apply_capacity(params, cfg, x, ident, capacity=256)
    # dropping must change results (overflowed tokens fall back to 0)
    assert not np.allclose(np.asarray(y_small), np.asarray(y_big))
    assert jnp.isfinite(y_small).all()


@given(st.integers(1, 30), st.integers(1, 8), st.integers(1, 4))
@settings(max_examples=20, deadline=None)
def test_moe_dispatch_slots_unique(n_tokens, n_experts, k):
    k = min(k, n_experts)
    rng = np.random.default_rng(n_tokens * 31 + n_experts)
    top_e = jnp.array(rng.integers(0, n_experts, (1, n_tokens, k)))
    top_p = jnp.ones((1, n_tokens, k), jnp.float32) / k
    cap = 4
    slot, w = L.moe_dispatch_indices(top_e, top_p, n_experts, cap)
    # no two kept (expert, slot) pairs may collide
    kept = [(int(e), int(s)) for e, s, ww in
            zip(np.asarray(top_e).ravel(), np.asarray(slot).ravel(),
                np.asarray(w).ravel()) if s < cap and ww > 0]
    assert len(kept) == len(set(kept))
    assert (np.asarray(slot) <= cap).all()


# ------------------------------------------------------------------ misc
def test_rope_rotation_preserves_norm():
    rng = np.random.default_rng(0)
    x = jnp.array(rng.normal(size=(1, 8, 2, 16)), jnp.float32)
    cos, sin = L.rope_angles(jnp.arange(8), 16, 1e4)
    y = L.apply_rope(x, cos, sin)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(x), axis=-1),
        np.linalg.norm(np.asarray(y), axis=-1), rtol=1e-5)


def test_rms_norm_unit_scale():
    x = jnp.full((2, 4, 8), 3.0, jnp.float32)
    y = L.rms_norm(x, jnp.ones((8,)), 1e-6)
    np.testing.assert_allclose(np.asarray(y), np.ones((2, 4, 8)),
                               rtol=1e-5)


def test_cross_entropy_masks_padded_vocab():
    logits = jnp.zeros((1, 4, 16))
    labels = jnp.array([[1, 2, -1, 3]])
    loss = L.cross_entropy(logits, labels, vocab_size=10)
    assert float(loss) == pytest.approx(math.log(16), rel=1e-5)
