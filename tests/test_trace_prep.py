"""Azure-2021 trace preprocessing (`scripts/prepare_azure_trace.py`)
and the generator's windowed columnar emission — pure-numpy paths, no
engine involved."""
import importlib.util
import os
import sys

import numpy as np
import pytest

from repro.core.request import Trace
from repro.traces import synth_azure_arrays, synth_azure_windows

_SCRIPT = os.path.join(os.path.dirname(__file__), os.pardir,
                       "scripts", "prepare_azure_trace.py")


def _load_script():
    spec = importlib.util.spec_from_file_location(
        "prepare_azure_trace", _SCRIPT)
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


@pytest.fixture(scope="module")
def prep():
    return _load_script()


def _fake_invocations():
    # completion-stamped, deliberately out of arrival order; one
    # sub-millisecond duration to exercise the 1 ms floor
    funcs = ["f-b", "f-a", "f-b", "f-c", "f-a", "f-b"]
    end_ts = [105.0, 101.0, 103.5, 110.0, 104.0, 102.0]
    durs = [2.0, 0.5, 1.5, 0.0004, 1.0, 0.25]
    return funcs, end_ts, durs


def test_convert_invocations_semantics(prep):
    funcs, end_ts, durs = _fake_invocations()
    a = prep.convert_invocations(funcs, end_ts, durs, seed=1)
    arr = a["arrival"]
    assert arr[0] == 0.0                       # shifted to t = 0
    assert np.all(np.diff(arr) >= 0)           # arrival-sorted
    assert np.all(a["exec_time"] >= 1e-3)      # 1 ms floor
    # arrivals: end - dur = [103.0, 100.5, 102.0, 109.9996, 103.0, 101.75]
    # sorted order: f-a(100.5), f-b(101.75), f-b(102.0), f-b(103.0),
    #               f-a(103.0), f-c(109.9996) — ids dense by first seen
    np.testing.assert_array_equal(a["fn_id"], [0, 1, 1, 1, 0, 2])
    assert len(a["cold_start"]) == 3 == len(a["evict"])
    assert np.all((a["cold_start"] >= 0.5) & (a["cold_start"] <= 1.5))
    # seeded draws are reproducible
    b = prep.convert_invocations(funcs, end_ts, durs, seed=1)
    np.testing.assert_array_equal(a["cold_start"], b["cold_start"])


def test_convert_head_truncates_earliest_arrivals(prep):
    funcs, end_ts, durs = _fake_invocations()
    a = prep.convert_invocations(funcs, end_ts, durs, head=3)
    assert len(a["fn_id"]) == 3
    full = prep.convert_invocations(funcs, end_ts, durs)
    np.testing.assert_allclose(a["arrival"], full["arrival"][:3])
    # function catalogue covers only the kept slice
    assert len(a["cold_start"]) == len(np.unique(a["fn_id"]))


def test_cli_roundtrips_through_trace_load_npz(prep, tmp_path):
    funcs, end_ts, durs = _fake_invocations()
    csv_path = tmp_path / "azure.csv"
    with open(csv_path, "w") as f:
        f.write("app,func,end_timestamp,duration\n")   # header skipped
        for fn, t, d in zip(funcs, end_ts, durs):
            f.write(f"app-x,{fn},{t},{d}\n")
    out = tmp_path / "azure.npz"
    assert prep.main(["--csv", str(csv_path), "--out", str(out),
                      "--head", "6"]) == 0
    tr = Trace.load_npz(str(out))
    assert len(tr) == 6
    assert tr.n_functions == 3
    ref = prep.convert_invocations(funcs, end_ts, durs, head=6)
    np.testing.assert_allclose(
        [r.arrival for r in tr.requests], ref["arrival"])


def test_cli_missing_csv_exits_nonzero(prep, tmp_path):
    assert prep.main(["--csv", str(tmp_path / "nope.csv"),
                      "--out", str(tmp_path / "o.npz")]) == 2


def test_synth_azure_windows_partition_the_columns():
    full = synth_azure_arrays(n_functions=10, n_requests=500, seed=5)
    wins = list(synth_azure_windows(n_functions=10, n_requests=500,
                                    seed=5, window=128))
    assert [w["base"] for w in wins] == [0, 128, 256, 384]
    for key in ("fn_id", "arrival", "exec_time"):
        np.testing.assert_array_equal(
            np.concatenate([w[key] for w in wins]), full[key])
    for w in wins:
        np.testing.assert_array_equal(w["cold_start"],
                                      full["cold_start"])
        assert len(w["fn_id"]) <= 128
