"""Shared test session setup.

Enables JAX's persistent compilation cache for the scheduling-engine
test modules: the engine jit-specialises per (kernel, capacity, ...)
tuple and those compiles dominate the engine tests' wall time — with
the disk cache a repeat run loads compiled executables instead of
re-invoking XLA.

The cache is scoped to the engine modules instead of the whole session
because this JAX build miscompiles *deserialized* executables for the
donated-buffer training step (test_checkpoint's crash-restart test
resumes training from garbage parameters when the second compile of
the same step function becomes a cache hit). The engine's executables
round-trip correctly — `benchmarks/run.py --smoke` re-verifies
request-for-request equivalence against the Python engine on every
cached run. The model/arch tests gain nothing from the cache anyway
(their time is tracing + execution, measured, not XLA compiles).

`repro.utils.jit_cache` holds the knob-flipping (importing
repro.core.jax_engine here would flip the global x64 flag, and the
kernel/model tests expect JAX's default f32 world until they opt in
themselves).
"""
import pytest

from repro.utils.jit_cache import (disable_compilation_cache,
                                   enable_compilation_cache)

# modules whose compiles are safe to persist (scheduling engine only)
_CACHED_MODULES = ("test_jax_engine", "test_jax_sim", "test_streaming",
                   "test_api", "test_cluster", "test_resilience",
                   "test_analysis")


@pytest.fixture(autouse=True)
def _persistent_cache_for_engine_tests(request):
    name = getattr(request.module, "__name__", "")
    if any(m in name for m in _CACHED_MODULES):
        enable_compilation_cache()
        yield
        disable_compilation_cache()
    else:
        yield
