"""Degrade hypothesis property tests to skips when hypothesis is absent.

``from tests._hypothesis_compat import given, settings, st`` behaves
exactly like the real hypothesis imports when the package is installed.
Without it, ``@given(...)`` marks just that test as skipped — the rest
of the module still runs (a module-level ``importorskip`` would drop
every test in the file, hypothesis-based or not).
"""
import pytest

try:
    import hypothesis.strategies as st
    from hypothesis import given, settings
except ImportError:  # pragma: no cover - exercised only without the dep
    class _Inert:
        """Absorbs any strategy-construction chain at decoration time."""

        def __call__(self, *args, **kwargs):
            return self

        def __getattr__(self, name):
            return self

    st = _Inert()

    def given(*args, **kwargs):
        return lambda fn: pytest.mark.skip(
            reason="hypothesis not installed")(fn)

    def settings(*args, **kwargs):
        return lambda fn: fn
