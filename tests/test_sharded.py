"""Distributed-numerics tests on a multi-device host mesh.

These run in a subprocess because the placeholder device count must be
set before jax initialises (the main test process keeps 1 device, per
the dry-run isolation rule).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import json
    import jax
    import jax.numpy as jnp
    import numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.configs import get_arch
    from repro.distributed.sharding import ShardingRules, Sharder, \\
        logical_to_pspec
    from repro.models import build_model
    from repro.train.data import synthetic_lm_batch

    assert len(jax.devices()) == 8
    mesh = jax.make_mesh((2, 4), ("data", "model"))

    out = {}
    for arch in ("qwen3-4b", "deepseek-moe-16b", "mamba2-780m"):
        cfg = get_arch(arch).smoke().replace(param_dtype="float32",
                                             compute_dtype="float32")
        model = build_model(cfg)
        params, axes = model.init(jax.random.key(0))
        batch = {k: jnp.asarray(v)
                 for k, v in synthetic_lm_batch(cfg, 4, 32, 0).items()}

        loss_local = float(jax.jit(
            lambda p, b: model.loss(p, b)[0])(params, batch))

        rules = ShardingRules.for_config(cfg, mesh, "train")
        sharder = Sharder(mesh, rules)
        specs = logical_to_pspec(axes, rules)
        sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                          is_leaf=lambda x: isinstance(x, P))
        params_sh = jax.tree.map(jax.device_put, params, sh)
        bsh = {k: jax.device_put(v, NamedSharding(
            mesh, P(*( ("data",) + (None,)*(v.ndim-1) ))))
            for k, v in batch.items()}
        loss_sharded = float(jax.jit(
            lambda p, b: model.loss(p, b, sharder)[0])(params_sh, bsh))
        out[arch] = (loss_local, loss_sharded)

    # sequence-parallel attention (indivisible head count) numerics
    cfg = get_arch("qwen3-4b").smoke().replace(
        n_heads=6, n_kv_heads=2, head_dim=16, d_model=96, d_ff=192,
        param_dtype="float32", compute_dtype="float32", attn_chunk=16)
    model = build_model(cfg)
    params, axes = model.init(jax.random.key(1))
    batch = {k: jnp.asarray(v)
             for k, v in synthetic_lm_batch(cfg, 4, 32, 1).items()}
    loss_local = float(jax.jit(
        lambda p, b: model.loss(p, b)[0])(params, batch))
    rules = ShardingRules.for_config(cfg, mesh, "train")
    assert rules.rules.get("_seq_attn"), "seq-attn rule not active"
    sharder = Sharder(mesh, rules)
    specs = logical_to_pspec(axes, rules)
    sh = jax.tree.map(lambda s: NamedSharding(mesh, s), specs,
                      is_leaf=lambda x: isinstance(x, P))
    params_sh = jax.tree.map(jax.device_put, params, sh)
    bsh = {k: jax.device_put(v, NamedSharding(
        mesh, P(*(("data",) + (None,)*(v.ndim-1)))))
        for k, v in batch.items()}
    loss_sp = float(jax.jit(
        lambda p, b: model.loss(p, b, sharder)[0])(params_sh, bsh))
    out["seq_attn_6h"] = (loss_local, loss_sp)
    print("RESULT" + json.dumps(out))
""")


@pytest.mark.slow
def test_sharded_loss_matches_single_device():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    env.pop("XLA_FLAGS", None)
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=900,
                       cwd=os.path.dirname(os.path.dirname(__file__)))
    assert r.returncode == 0, r.stderr[-3000:]
    line = [l for l in r.stdout.splitlines()
            if l.startswith("RESULT")][0]
    res = json.loads(line[len("RESULT"):])
    for arch, (a, b) in res.items():
        assert abs(a - b) / max(abs(a), 1e-6) < 2e-2, (arch, a, b)
