#!/usr/bin/env bash
# CI entrypoint: tier-1 test suite + the engine smoke gate + the
# jaxpr/HLO invariant auditor.
#
#   bash scripts/ci.sh            # everything (what CI runs on push)
#   bash scripts/ci.sh tests      # tier-1 only
#   bash scripts/ci.sh smoke      # smoke gate only
#   bash scripts/ci.sh analysis   # invariant gates only
#
# Tier-1 is the repo's correctness bar (ROADMAP.md); the smoke gate
# re-verifies request-for-request Python/JAX engine equivalence, the
# streaming/exact + sweep-shim + cluster-K=1 + npz-round-trip bitwise
# gates, the churn rail (conservation under mid-window node death,
# trivial-schedule lowering, all-down park/resume), the resilience
# rail (trivial fault knobs lower bitwise, faults + shedding conserve
# every request, the circuit breaker trips and recovers), the
# telemetry rail (trace_events=False bitwise on every tier, traced-run
# conservation + span reassembly, Perfetto schema), 2-device sharded
# parity and the deprecated-entry-point scan. The smoke stage writes
# BENCH_smoke.json (gate lines + wall + provenance), appends a row to
# the cumulative BENCH_history.jsonl, and emits
# trace_sample_perfetto.json — CI uploads all three as artifacts (the
# trace opens directly in ui.perfetto.dev).
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

stage="${1:-all}"

if [[ "$stage" == "all" || "$stage" == "tests" ]]; then
    echo "== tier-1: pytest =="
    python -m pytest -x -q
fi

if [[ "$stage" == "all" || "$stage" == "smoke" ]]; then
    echo "== smoke gate: benchmarks/run.py --smoke =="
    python -m benchmarks.run --smoke --json BENCH_smoke.json \
        --history BENCH_history.jsonl
fi

if [[ "$stage" == "all" || "$stage" == "analysis" ]]; then
    echo "== invariant gates: python -m repro.analysis =="
    python -m repro.analysis --out analysis_report.json
fi

echo "== ci.sh: OK =="
