"""Preprocess the Azure Functions 2021 invocation trace into the
engine's ``Trace.load_npz`` format.

The paper (§VI) evaluates on the first 6e5 requests of
*AzureFunctionsInvocationTraceForTwoWeeksJan2021* [Zhang et al.,
SOSP'21] — a CSV of per-invocation records ``(app, func,
end_timestamp, duration)``. That dataset is not redistributable inside
this repository; download it per ``docs/azure_trace.md`` and run::

    PYTHONPATH=src python scripts/prepare_azure_trace.py \
        --csv AzureFunctionsInvocationTraceForTwoWeeksJan2021.txt \
        --out data/azure_2021_600k.npz --head 600000

The output npz holds the five columnar arrays the engine consumes
(``fn_id`` / ``arrival`` / ``exec_time`` / ``cold_start`` / ``evict``)
and is declared to experiments as ``repro.api.NpzTrace(path)`` — the
trace source fig5-fig8 and ``benchmarks.engine_scale --trace`` run
when pointed at it (see docs/api.md and docs/azure_trace.md).

Preprocessing semantics (documented in docs/azure_trace.md):

* arrival  = end_timestamp - duration (the trace records completion
  times), shifted so the earliest arrival is t = 0;
* requests are sorted by (arrival, input order) and truncated to the
  first ``--head`` (paper: 6e5);
* exec_time = duration floored at 1 ms (the paper's "0 ms -> 1 ms"
  quantisation floor);
* functions are the distinct ``func`` hashes of the *kept* slice,
  numbered densely in order of first appearance;
* cold_start / evict latencies are not in the dataset — they are
  sampled once per function from U[0.5, 1.5] s (paper §VI-A, from the
  ServerlessBench characterisation), seeded for reproducibility.
"""
from __future__ import annotations

import argparse
import csv
import os
import sys

import numpy as np


def convert_invocations(funcs, end_ts, durations, *, head=None,
                        seed=0, cold_range=(0.5, 1.5),
                        min_exec=1e-3) -> dict:
    """Pure conversion: invocation columns -> ``Trace.load_npz`` arrays.

    ``funcs`` are opaque function identifiers (hash strings); ``end_ts``
    and ``durations`` are float seconds. Returns the five-array dict
    (arrival-sorted, fn ids dense in order of first appearance within
    the kept slice).
    """
    end_ts = np.asarray(end_ts, np.float64)
    durations = np.asarray(durations, np.float64)
    arrival = end_ts - durations
    order = np.argsort(arrival, kind="stable")
    if head is not None:
        order = order[:int(head)]
    arrival = arrival[order]
    arrival -= arrival[0] if len(arrival) else 0.0
    exec_time = np.maximum(durations[order], min_exec)

    ids: dict = {}
    fn_id = np.empty(len(order), np.int32)
    for i, src in enumerate(np.asarray(funcs, object)[order]):
        fn_id[i] = ids.setdefault(src, len(ids))

    rng = np.random.default_rng(seed)
    cold = rng.uniform(*cold_range, len(ids))
    evict = rng.uniform(*cold_range, len(ids))
    return dict(fn_id=fn_id, arrival=arrival,
                exec_time=exec_time.astype(np.float64),
                cold_start=cold.astype(np.float64),
                evict=evict.astype(np.float64))


def read_invocation_csv(path):
    """Stream the Azure CSV -> (funcs, end_ts, durations) lists.

    Accepts the published schema ``app,func,end_timestamp,duration``
    (header optional, extra columns ignored)."""
    funcs, end_ts, durations = [], [], []
    with open(path, newline="") as f:
        reader = csv.reader(f)
        for row in reader:
            if not row or len(row) < 4:
                continue
            try:
                t, d = float(row[2]), float(row[3])
            except ValueError:
                continue          # header line
            funcs.append(row[1])
            end_ts.append(t)
            durations.append(d)
    return funcs, end_ts, durations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--csv", required=True,
                    help="AzureFunctionsInvocationTrace...Jan2021 CSV")
    ap.add_argument("--out", required=True, help="output .npz path")
    ap.add_argument("--head", type=int, default=600_000,
                    help="keep the first N arrivals (paper: 6e5)")
    ap.add_argument("--seed", type=int, default=0,
                    help="seed for the U[cold-range] latency draws")
    ap.add_argument("--cold-range", type=float, nargs=2,
                    default=(0.5, 1.5), metavar=("LO", "HI"),
                    help="cold-start/evict latency range in seconds")
    args = ap.parse_args(argv)

    if not os.path.exists(args.csv):
        print(f"error: {args.csv} not found — see docs/azure_trace.md "
              "for how to obtain the dataset", file=sys.stderr)
        return 2
    funcs, end_ts, durations = read_invocation_csv(args.csv)
    if not funcs:
        print(f"error: no invocation rows parsed from {args.csv}",
              file=sys.stderr)
        return 2
    a = convert_invocations(funcs, end_ts, durations, head=args.head,
                            seed=args.seed,
                            cold_range=tuple(args.cold_range))
    out_dir = os.path.dirname(os.path.abspath(args.out))
    os.makedirs(out_dir, exist_ok=True)
    np.savez_compressed(args.out, **a)
    dur = a["arrival"][-1] if len(a["arrival"]) else 0.0
    print(f"wrote {args.out}: {len(a['fn_id'])} requests, "
          f"{len(a['cold_start'])} functions, span {dur / 3600:.1f} h")
    return 0


if __name__ == "__main__":
    sys.exit(main())
